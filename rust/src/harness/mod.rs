//! Experiment harness: the FlexAI-specific plumbing the typed plan/engine
//! API cannot own — PJRT runtime loading, the FlexAI registry factory
//! (checkpoint restore or fresh parameters) and the training loop.
//!
//! Queue construction and multi-queue evaluation moved to `plan` /
//! `engine`: build an [`ExperimentPlan`](crate::plan::ExperimentPlan),
//! run it on an [`Engine`](crate::engine::Engine) with a registry from
//! [`registry`].  See rust/DESIGN.md for the migration table.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{EnvConfig, ExperimentConfig};
use crate::env::route::{Route, RouteParams};
use crate::env::taskgen::{self, TaskQueue};
use crate::metrics::summary::RunSummary;
use crate::runtime::Runtime;
use crate::sched::flexai::{checkpoint, FlexAI, FlexAIConfig};
use crate::sched::registry::Factory;
use crate::sched::{Registry, SchedulerSpec};
use crate::sim::{simulate, SimOptions};
use crate::util::rng::Rng;

/// A single training-route queue.  Route length cycles through
/// {0.75×, 1×, 1.5×} of the base distance so the policy sees several
/// route scales (eval routes are longer than training routes).
pub fn make_training_queue(env: &EnvConfig, distance_m: f64, episode: usize) -> TaskQueue {
    let mut rng = Rng::new(env.seed ^ 0xace1_u64);
    let mut stream = rng.fork(1000 + episode as u64);
    let scale = [0.75, 1.0, 1.5][episode % 3];
    let route =
        Route::generate(RouteParams::for_area(env.area, distance_m * scale), &mut stream);
    taskgen::generate(&route)
}

thread_local! {
    /// Per-thread runtime cache: compiling the four HLO executables is the
    /// expensive part of FlexAI construction, and `Runtime` is not `Send`
    /// under the `pjrt` feature, so each engine worker (or the main
    /// thread) loads once and reuses it for all its trials.
    static RUNTIME_CACHE: std::cell::RefCell<Option<Arc<Runtime>>> =
        const { std::cell::RefCell::new(None) };
}

/// Load the PJRT runtime, cached per thread (FlexAI paths only).
/// Failures (missing artifacts / stub build) are not cached, so creating
/// artifacts and retrying in the same process works.
pub fn load_runtime() -> Result<Arc<Runtime>> {
    RUNTIME_CACHE.with(|cell| {
        if let Some(rt) = cell.borrow().as_ref() {
            return Ok(rt.clone());
        }
        let rt = Arc::new(Runtime::load_default().context(
            "loading AOT artifacts — run `make artifacts` first",
        )?);
        *cell.borrow_mut() = Some(rt.clone());
        Ok(rt)
    })
}

/// Registry factory for FlexAI: loads the spec's checkpoint when set,
/// otherwise fresh seeded parameters; always inference mode.  The PJRT
/// runtime is loaded lazily on whichever engine worker builds the agent —
/// FlexAI never crosses a thread boundary.
pub fn flexai_factory(base: FlexAIConfig) -> Factory {
    Arc::new(move |spec, ctx| {
        let rt = load_runtime()?;
        let cfg = FlexAIConfig { seed: ctx.seed, ..base.clone() };
        let ckpt = match spec {
            SchedulerSpec::FlexAI { checkpoint } => checkpoint.as_deref(),
            _ => None,
        };
        let agent = match ckpt {
            Some(path) if !path.is_empty() => checkpoint::load(rt, Path::new(path), cfg)?,
            _ => {
                let mut a = FlexAI::new(rt, cfg)?;
                a.set_training(false);
                a
            }
        };
        Ok(Box::new(agent) as Box<dyn crate::sched::Scheduler>)
    })
}

/// The full scheduler registry for a config: every baseline plus FlexAI
/// (greedy-inference hyper-parameters from `cfg`).
pub fn registry(cfg: &ExperimentConfig) -> Registry {
    let mut r = Registry::new();
    r.register("flexai", flexai_factory(cfg.flexai_infer_config()));
    r
}

/// Specs for every scheduler with a registered factory *except* FlexAI,
/// which needs a runtime-resolved checkpoint — callers prepend their own
/// FlexAI spec when the PJRT runtime is available (see bench_scenarios /
/// scenario_tour).
pub fn registered_non_flexai_specs(reg: &Registry) -> Vec<SchedulerSpec> {
    reg.registered()
        .into_iter()
        .filter(|n| *n != "flexai")
        .map(|n| SchedulerSpec::parse(n).expect("registered names parse"))
        .collect()
}

/// Result of a FlexAI training run.
pub struct TrainOutcome {
    pub agent: FlexAI,
    /// TD loss per train step, across all episodes (Fig. 11).
    pub losses: Vec<f32>,
    /// (episode, tasks, stm_rate, mean reward proxy) per episode.
    pub episode_summaries: Vec<RunSummary>,
}

/// Train FlexAI per §8.3: one episode = one task queue; ε-greedy decays
/// across episodes; TargNet syncs on the configured cadence.
pub fn train_flexai(cfg: &ExperimentConfig) -> Result<TrainOutcome> {
    let rt = load_runtime()?;
    let platform = cfg.platform()?;
    let mut agent = FlexAI::new(rt, cfg.flexai_config())?;
    agent.set_training(true);
    let mut episode_summaries = Vec::new();
    for ep in 0..cfg.train.episodes {
        let queue = make_training_queue(&cfg.env, cfg.train.episode_distance_m, ep);
        let r = simulate(&queue, &platform, &mut agent, SimOptions::default());
        agent.end_episode();
        episode_summaries.push(r.summary);
    }
    agent.set_training(false);
    let losses = agent.losses.clone();
    Ok(TrainOutcome { agent, losses, episode_summaries })
}

#[cfg(test)]
#[allow(clippy::print_stderr)] // self-skipping tests explain themselves
mod tests {
    use super::*;
    use crate::env::Area;

    #[test]
    fn training_queues_are_deterministic_and_scale_cycled() {
        let env = EnvConfig { area: Area::Urban, distances_m: vec![100.0], seed: 5 };
        let a = make_training_queue(&env, 100.0, 0);
        let b = make_training_queue(&env, 100.0, 0);
        assert_eq!(a.len(), b.len());
        // Episode 2 uses the 1.5× route scale — strictly more tasks.
        let longer = make_training_queue(&env, 100.0, 2);
        assert!(longer.len() > a.len());
    }

    #[test]
    fn registry_covers_baselines_and_flexai() {
        let cfg = ExperimentConfig::default();
        let reg = registry(&cfg);
        for name in crate::sched::baseline_names() {
            assert!(reg.build_by_name(name, cfg.env.seed).is_ok(), "{name}");
        }
        assert!(reg.build_by_name("bogus", 0).is_err());
        // FlexAI has a factory; whether it builds depends on artifacts.
        assert!(reg.registered().contains(&"flexai"));
        if let Err(e) = reg.build(&SchedulerSpec::FlexAI { checkpoint: None }, cfg.env.seed) {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("artifacts") || msg.contains("pjrt"),
                "unexpected flexai error: {msg}"
            );
        }
    }

    #[test]
    fn non_flexai_specs_cover_every_registered_baseline() {
        let reg = registry(&ExperimentConfig::default());
        let specs = registered_non_flexai_specs(&reg);
        assert_eq!(specs.len(), reg.registered().len() - 1, "only flexai excluded");
        for spec in &specs {
            assert_ne!(spec.canonical(), "flexai");
            assert!(reg.build(spec, 1).is_ok(), "{}", spec.canonical());
        }
    }

    #[test]
    fn train_one_tiny_episode() {
        if Runtime::load_default().is_err() {
            eprintln!("skipping train_one_tiny_episode: PJRT artifacts unavailable");
            return;
        }
        let cfg = ExperimentConfig {
            train: crate::config::TrainConfig {
                episodes: 1,
                episode_distance_m: 40.0,
                checkpoint: String::new(),
            },
            ..Default::default()
        };
        let out = train_flexai(&cfg).expect("artifacts present");
        assert_eq!(out.episode_summaries.len(), 1);
        assert!(out.episode_summaries[0].tasks > 100);
        assert!(!out.agent.is_training());
    }
}
