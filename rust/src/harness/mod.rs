//! Experiment harness: the shared plumbing between the CLI, the examples
//! and the per-figure benches — queue construction, scheduler construction
//! (including FlexAI with its PJRT runtime), training loops and
//! multi-queue evaluation.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{EnvConfig, ExperimentConfig};
use crate::env::route::{Route, RouteParams};
use crate::env::taskgen::{self, TaskQueue};
use crate::metrics::summary::RunSummary;
use crate::platform::Platform;
use crate::runtime::Runtime;
use crate::sched::flexai::{checkpoint, FlexAI};
use crate::sched::Scheduler;
use crate::sim::{simulate, SimOptions, SimResult};
use crate::util::rng::Rng;

/// Build one task queue per configured route distance.  Queue `i` uses a
/// deterministic sub-stream of the seed, so adding distances never changes
/// existing queues.
pub fn make_queues(env: &EnvConfig) -> Vec<TaskQueue> {
    make_queues_with_deadline(env, taskgen::DeadlineMode::Rss)
}

/// `make_queues` with an explicit deadline regime (Fig. 13's second table).
pub fn make_queues_with_deadline(
    env: &EnvConfig,
    mode: taskgen::DeadlineMode,
) -> Vec<TaskQueue> {
    let mut rng = Rng::new(env.seed);
    env.distances_m
        .iter()
        .enumerate()
        .map(|(i, &d)| {
            let mut stream = rng.fork(i as u64);
            let route = Route::generate(RouteParams::for_area(env.area, d), &mut stream);
            taskgen::generate_with_deadline(&route, mode)
        })
        .collect()
}

/// A single training-route queue.  Route length cycles through
/// {0.75×, 1×, 1.5×} of the base distance so the policy sees several
/// route scales (eval routes are longer than training routes).
pub fn make_training_queue(env: &EnvConfig, distance_m: f64, episode: usize) -> TaskQueue {
    let mut rng = Rng::new(env.seed ^ 0xace1_u64);
    let mut stream = rng.fork(1000 + episode as u64);
    let scale = [0.75, 1.0, 1.5][episode % 3];
    let route =
        Route::generate(RouteParams::for_area(env.area, distance_m * scale), &mut stream);
    taskgen::generate(&route)
}

/// Load the PJRT runtime once (FlexAI paths only).
pub fn load_runtime() -> Result<Arc<Runtime>> {
    Ok(Arc::new(Runtime::load_default().context(
        "loading AOT artifacts — run `make artifacts` first",
    )?))
}

/// Construct the configured scheduler.  For FlexAI: loads the checkpoint
/// when set, otherwise fresh seeded parameters, always inference mode.
pub fn make_scheduler(cfg: &ExperimentConfig) -> Result<Box<dyn Scheduler>> {
    if cfg.scheduler.eq_ignore_ascii_case("flexai") {
        let rt = load_runtime()?;
        let agent = if cfg.checkpoint.is_empty() {
            let mut a = FlexAI::new(rt, cfg.flexai_infer_config())?;
            a.set_training(false);
            a
        } else {
            checkpoint::load(rt, std::path::Path::new(&cfg.checkpoint), cfg.flexai_infer_config())?
        };
        Ok(Box::new(agent))
    } else {
        crate::sched::by_name(&cfg.scheduler, cfg.env.seed)
            .with_context(|| format!("unknown scheduler '{}'", cfg.scheduler))
    }
}

/// Evaluate one scheduler over all queues; `reset` between queues.
pub fn run_queues(
    queues: &[TaskQueue],
    platform: &Platform,
    scheduler: &mut dyn Scheduler,
    opts: SimOptions,
) -> Vec<SimResult> {
    queues
        .iter()
        .map(|q| {
            scheduler.reset();
            simulate(q, platform, scheduler, opts)
        })
        .collect()
}

/// Result of a FlexAI training run.
pub struct TrainOutcome {
    pub agent: FlexAI,
    /// TD loss per train step, across all episodes (Fig. 11).
    pub losses: Vec<f32>,
    /// (episode, tasks, stm_rate, mean reward proxy) per episode.
    pub episode_summaries: Vec<RunSummary>,
}

/// Train FlexAI per §8.3: one episode = one task queue; ε-greedy decays
/// across episodes; TargNet syncs on the configured cadence.
pub fn train_flexai(cfg: &ExperimentConfig) -> Result<TrainOutcome> {
    let rt = load_runtime()?;
    let platform = cfg.platform()?;
    let mut agent = FlexAI::new(rt, cfg.flexai_config())?;
    agent.set_training(true);
    let mut episode_summaries = Vec::new();
    for ep in 0..cfg.train.episodes {
        let queue = make_training_queue(&cfg.env, cfg.train.episode_distance_m, ep);
        let r = simulate(&queue, &platform, &mut agent, SimOptions::default());
        agent.end_episode();
        episode_summaries.push(r.summary);
    }
    agent.set_training(false);
    let losses = agent.losses.clone();
    Ok(TrainOutcome { agent, losses, episode_summaries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::Area;

    #[test]
    fn queues_are_deterministic_and_distance_scaled() {
        let env = EnvConfig {
            area: Area::Urban,
            distances_m: vec![100.0, 200.0],
            seed: 5,
        };
        let a = make_queues(&env);
        let b = make_queues(&env);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].len(), b[0].len());
        assert!(a[1].len() > a[0].len(), "longer route, more tasks");
        // Adding a distance does not perturb earlier queues.
        let env3 = EnvConfig { distances_m: vec![100.0, 200.0, 300.0], ..env };
        let c = make_queues(&env3);
        assert_eq!(c[0].len(), a[0].len());
        assert_eq!(c[1].len(), a[1].len());
    }

    #[test]
    fn make_scheduler_baselines() {
        let mut cfg = ExperimentConfig::default();
        for name in crate::sched::BASELINES {
            cfg.scheduler = name.into();
            assert!(make_scheduler(&cfg).is_ok(), "{name}");
        }
        cfg.scheduler = "bogus".into();
        assert!(make_scheduler(&cfg).is_err());
    }

    #[test]
    fn train_one_tiny_episode() {
        let cfg = ExperimentConfig {
            train: crate::config::TrainConfig {
                episodes: 1,
                episode_distance_m: 40.0,
                checkpoint: String::new(),
            },
            ..Default::default()
        };
        let out = train_flexai(&cfg).expect("artifacts present");
        assert_eq!(out.episode_summaries.len(), 1);
        assert!(out.episode_summaries[0].tasks > 100);
        assert!(!out.agent.is_training());
    }
}
