//! Per-dataflow analytical cycle models for the three HMAI sub-accelerators.
//!
//! Each model combines *structural fit* terms — how well the layer's shape
//! tiles onto the PE array (ceil-division remainders) — with a
//! *dataflow-affinity* efficiency constant per operator class that captures
//! the serialization each architecture pays (weight streaming on dispersed
//! registers, broadcast serialization on ShiDianNao-style arrays, window
//! mapping on Origami-style channel-block arrays).  The constants are
//! calibrated so the per-network FPS reproduces Table 8's ordering and
//! magnitudes (see tests + EXPERIMENTS.md):
//!
//! | FPS      | SconvOD | SconvIC | MconvMC |
//! |----------|---------|---------|---------|
//! | YOLO     | 170.37  | 132.54  | 149.32  |
//! | SSD      |  74.99  |  82.94  |  82.57  |
//! | GOTURN   | 352.69  | 350.34  | 500.54  |

use super::{AccelKind, CoreSize, LayerCost, MACS_PER_ACCEL};
use crate::workload::{Layer, LayerKind};

/// PE-array geometry of a *standard* core.
const OD_ROWS: f64 = 64.0; // SconvOD: rows hold kxk x Tc filter taps
const OD_COLS: f64 = 64.0; // SconvOD: columns hold output channels
const IC_PES: f64 = 4096.0; // SconvIC: 64x64 output-pixel PEs
const MM_TC: f64 = 16.0; // MconvMC: Tm = Tc = 16 channel block

/// Concrete PE-array geometry of one core, derived from its [`CoreSize`].
/// One dimension of each array scales with the MAC budget — the kernel-tap
/// rows (SconvOD) and the input-channel block (MconvMC) are dataflow
/// invariants, so the *other* dimension absorbs the provisioning:
/// SconvOD grows output-channel columns, SconvIC grows the output-pixel
/// array, MconvMC grows the output-channel block Tm.  At `Std` every value
/// equals the constants above (multiplication by `scale = 1.0` is exact in
/// IEEE 754, so the standard path is bit-identical to the pre-size model).
struct CoreGeom {
    macs: f64,
    od_rows: f64,
    od_cols: f64,
    ic_pes: f64,
    mm_tm: f64,
    mm_tc: f64,
}

fn geom(size: CoreSize) -> CoreGeom {
    let s = size.scale();
    CoreGeom {
        macs: MACS_PER_ACCEL as f64 * s,
        od_rows: OD_ROWS,
        od_cols: OD_COLS * s,
        ic_pes: IC_PES * s,
        mm_tm: MM_TC * s,
        mm_tc: MM_TC,
    }
}

/// Operator class for affinity lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OpClass {
    Conv1x1,
    Conv3x3,
    ConvLargeK,
    Fc,
}

fn op_class(k: usize, kind: &LayerKind) -> OpClass {
    match kind {
        LayerKind::Fc => OpClass::Fc,
        LayerKind::Conv { .. } if k == 1 => OpClass::Conv1x1,
        LayerKind::Conv { .. } if k <= 3 => OpClass::Conv3x3,
        _ => OpClass::ConvLargeK,
    }
}

/// Dataflow-affinity efficiency (0..1): the fraction of peak the dataflow
/// sustains on a perfectly-tiling layer of that operator class.
fn affinity(accel: AccelKind, op: OpClass) -> f64 {
    use AccelKind::*;
    use OpClass::*;
    match (accel, op) {
        // SconvOD: weights pinned in dispersed PE registers -> superb 2-D
        // conv reuse; large kernels split across tap passes; FC streams
        // weights through DR, which is its worst case.
        (SconvOD, Conv3x3) => 0.96,
        (SconvOD, Conv1x1) => 0.88,
        (SconvOD, ConvLargeK) => 0.62,
        (SconvOD, Fc) => 0.22,
        // SconvIC: one weight broadcast per cycle serializes deep-channel
        // layers; shines on large spatial maps (output-pixel parallelism).
        (SconvIC, Conv3x3) => 0.74,
        (SconvIC, Conv1x1) => 0.70,
        (SconvIC, ConvLargeK) => 0.66,
        (SconvIC, Fc) => 0.28,
        // MconvMC: channel-block (Tm=Tc=16) processing -> native at deep
        // channels and matmul/FC; pays window-mapping overhead at 3x3 and
        // bandwidth underuse at 1x1.
        (MconvMC, Conv3x3) => 0.82,
        (MconvMC, Conv1x1) => 0.76,
        (MconvMC, ConvLargeK) => 0.90,
        (MconvMC, Fc) => 0.92,
    }
}

fn ceil_frac(x: f64, q: f64) -> f64 {
    // x / (q * ceil(x/q)): fraction of the q-quantized capacity used.
    if x <= 0.0 {
        return 1.0;
    }
    x / (q * (x / q).ceil())
}

/// Structural fit (0..1): tiling-remainder waste for this layer shape on
/// a core with geometry `g`.
fn structural_fit(accel: AccelKind, l: &Layer, k: usize, g: &CoreGeom) -> f64 {
    let (ic, oc) = (l.in_c as f64, l.out_c as f64);
    let spatial = (l.out_h * l.out_w) as f64;
    match accel {
        AccelKind::SconvOD => {
            // Rows hold kxk taps x as many input channels as fit; columns
            // hold the output channels.
            let kk = (k * k) as f64;
            let tap_rows = kk.min(g.od_rows);
            let tc_fit = (g.od_rows / kk).floor().max(1.0).min(ic);
            let row_util = (tap_rows * tc_fit).min(g.od_rows) / g.od_rows
                * ceil_frac(ic, tc_fit);
            let col_util = ceil_frac(oc, g.od_cols);
            row_util * col_util
        }
        AccelKind::SconvIC => {
            // Output pixels map onto the PE array; when the map is smaller
            // than the array, spare PEs fold in extra output channels.
            if spatial >= g.ic_pes {
                ceil_frac(spatial, g.ic_pes)
            } else {
                let ch_fold = (g.ic_pes / spatial).floor().max(1.0).min(oc);
                (spatial * ch_fold) / g.ic_pes * ceil_frac(oc, ch_fold)
            }
        }
        AccelKind::MconvMC => {
            // Tm x Tc channel blocks.
            ceil_frac(ic, g.mm_tc) * ceil_frac(oc, g.mm_tm)
        }
    }
}

/// Stride penalty: ShiDianNao-style ifmap shifting skips with stride > 1.
fn stride_penalty(accel: AccelKind, stride: usize) -> f64 {
    if accel == AccelKind::SconvIC && stride > 1 {
        1.0 / (1.0 + 0.18 * (stride as f64 - 1.0))
    } else {
        1.0
    }
}

/// EXMC / OCB / register access counts per dataflow (drives energy).
fn access_counts(accel: AccelKind, l: &Layer, cost: &mut LayerCost, g: &CoreGeom) {
    let b = l.branches as f64;
    let ifmap = l.input_elems() as f64;
    let ofmap = l.neurons() as f64;
    let weights = l.weights() as f64;
    let macs = cost.macs;
    match accel {
        AccelKind::SconvOD => {
            // NeuFlow claim (§5.2): each ifmap neuron fetched from EXMC
            // exactly once; weights pinned per pass; psums never leave PEs.
            cost.exmc_accesses += ifmap + ofmap + weights * b;
            // psum in + psum out + weight-reg read per MAC.
            cost.reg_accesses += 3.0 * macs;
        }
        AccelKind::SconvIC => {
            // Ifmaps propagate between PEs (IP); weights re-broadcast per
            // spatial tile (a bigger array → fewer tiles → fewer weight
            // re-fetches); CR (no psum storage) absorbs ifmap traffic.
            let tiles = ((l.out_h * l.out_w) as f64 / g.ic_pes).ceil().max(1.0);
            cost.exmc_accesses += ifmap + ofmap + weights * tiles * b;
            // ifmap shift + psum accumulate per MAC.
            cost.reg_accesses += 2.0 * macs;
        }
        AccelKind::MconvMC => {
            // OCB present (Table 10): ifmaps staged through SRAM A1/A2,
            // weights streamed once, psum tree accumulation (per
            // input-channel block, which does not scale with size).
            cost.exmc_accesses += ifmap + ofmap + weights * b;
            cost.ocb_accesses += ifmap + macs / g.mm_tc;
            cost.reg_accesses += 2.0 * macs;
        }
    }
}

/// Cycle + access cost of one layer on one *standard* sub-accelerator.
pub fn layer_cost(accel: AccelKind, l: &Layer) -> LayerCost {
    layer_cost_sized(accel, l, CoreSize::Std)
}

/// Cycle + access cost of one layer on one sub-accelerator of `size`.
/// Data-movement layers (pool/route/shortcut/upsample/detect) stream
/// through the fixed 256-lane EXMC interface, which does not scale with
/// the MAC array — only compute layers speed up with core size.
pub fn layer_cost_sized(accel: AccelKind, l: &Layer, size: CoreSize) -> LayerCost {
    let g = geom(size);
    let mut cost = LayerCost { macs: l.macs() as f64, ..Default::default() };
    match l.kind {
        LayerKind::Conv { k, stride, .. } => {
            let eff = affinity(accel, op_class(k, &l.kind))
                * structural_fit(accel, l, k, &g)
                * stride_penalty(accel, stride);
            cost.cycles = cost.macs / (g.macs * eff.max(1e-3));
            access_counts(accel, l, &mut cost, &g);
        }
        LayerKind::Fc => {
            let eff = affinity(accel, OpClass::Fc) * structural_fit(accel, l, 1, &g);
            cost.cycles = cost.macs / (g.macs * eff.max(1e-3));
            access_counts(accel, l, &mut cost, &g);
        }
        // Data-movement layers: streamed at one element per lane per cycle
        // through the EXMC interface (memory-bound).
        LayerKind::MaxPool { k, .. } => {
            let reads = l.input_elems() as f64 * ((k * k) as f64 / (k * k) as f64);
            cost.cycles = reads / 256.0; // 256 lanes of pooling comparators
            cost.exmc_accesses += l.input_elems() as f64 + l.neurons() as f64;
        }
        LayerKind::Shortcut | LayerKind::Route | LayerKind::Upsample | LayerKind::Detect => {
            cost.cycles = l.neurons() as f64 / 256.0;
            cost.exmc_accesses += l.input_elems() as f64 + l.neurons() as f64;
        }
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{task_cost, ALL_ACCELS};
    use crate::workload::ModelKind;

    /// Paper Table 8 (FPS).
    const TABLE8: [(ModelKind, [f64; 3]); 3] = [
        (ModelKind::Yolo, [170.37, 132.54, 149.32]),
        (ModelKind::Ssd, [74.99, 82.94, 82.57]),
        (ModelKind::Goturn, [352.69, 350.34, 500.54]),
    ];

    #[test]
    fn table8_ordering_holds() {
        for (m, fps) in TABLE8 {
            let ours: Vec<f64> = ALL_ACCELS.iter().map(|&a| task_cost(a, m).fps()).collect();
            // Same argmax / argmin accelerator as the paper.
            let argmax_paper = fps
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            let argmax_ours = ours
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            assert_eq!(argmax_paper, argmax_ours, "{m:?}: ours={ours:?} paper={fps:?}");
        }
    }

    #[test]
    fn table8_magnitudes_within_5pct() {
        for (m, fps) in TABLE8 {
            for (i, &a) in ALL_ACCELS.iter().enumerate() {
                let ours = task_cost(a, m).fps();
                let ratio = ours / fps[i];
                assert!(
                    (0.95..1.05).contains(&ratio),
                    "{:?} on {:?}: ours {ours:.1} vs paper {:.1} (ratio {ratio:.2})",
                    m,
                    a,
                    fps[i]
                );
            }
        }
    }

    #[test]
    fn structural_fit_bounds() {
        use crate::accel::ALL_SIZES;
        use crate::workload::model;
        for m in [ModelKind::Yolo, ModelKind::Ssd, ModelKind::Goturn] {
            for l in &model(m).layers {
                if let LayerKind::Conv { k, .. } = l.kind {
                    for a in ALL_ACCELS {
                        for s in ALL_SIZES {
                            let f = structural_fit(a, l, k, &geom(s));
                            assert!(f > 0.0 && f <= 1.0, "{a:?} {s:?} {}: fit={f}", l.name);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn std_geometry_matches_the_constants() {
        let g = geom(crate::accel::CoreSize::Std);
        assert_eq!(g.macs.to_bits(), (MACS_PER_ACCEL as f64).to_bits());
        assert_eq!(g.od_rows.to_bits(), OD_ROWS.to_bits());
        assert_eq!(g.od_cols.to_bits(), OD_COLS.to_bits());
        assert_eq!(g.ic_pes.to_bits(), IC_PES.to_bits());
        assert_eq!(g.mm_tm.to_bits(), MM_TC.to_bits());
        assert_eq!(g.mm_tc.to_bits(), MM_TC.to_bits());
    }

    #[test]
    fn fc_penalizes_dispersed_registers() {
        // §5.1: DR must stream FC weights; CR-based Mconv is near-native.
        assert!(affinity(AccelKind::MconvMC, OpClass::Fc) > 3.0 * affinity(AccelKind::SconvOD, OpClass::Fc));
    }

    #[test]
    fn movement_layers_have_no_macs() {
        use crate::workload::model;
        for l in &model(ModelKind::Yolo).layers {
            if !l.is_compute() {
                let c = layer_cost(AccelKind::SconvOD, l);
                assert_eq!(c.macs, 0.0);
                assert!(c.cycles > 0.0);
            }
        }
    }
}
