//! NVIDIA Tesla T4 roofline baseline (§8.2 Fig. 10).
//!
//! The paper compares HMAI against a T4 (65 TFLOPS fp16 peak, 70 W TDP).
//! We model it as a roofline with a per-network achieved-utilization factor
//! taken from published TensorRT-class inference studies: single-stream CNN
//! inference on T4 sustains ~8-15% of fp16 peak for detector-sized nets
//! (kernel launch + memory-bound layers dominate), which is what makes a
//! dataflow ASIC 5x faster at iso-workload in the paper.

use crate::workload::{model, ModelKind};

/// T4 peak fp16 throughput (tensor cores), ops/s.
pub const PEAK_FP16_OPS: f64 = 65e12;
/// T4 board power (TDP), watts.
pub const TDP_W: f64 = 70.0;

/// Achieved fraction of peak for one network (single-stream inference).
pub fn achieved_utilization(kind: ModelKind) -> f64 {
    match kind {
        // Deep uniform 3x3/1x1 stacks fuse well.
        ModelKind::Yolo => 0.115,
        // VGG-style heads + multi-scale gathers are launch-bound.
        ModelKind::Ssd => 0.135,
        // Small siamese branches underfill SMs.
        ModelKind::Goturn => 0.085,
    }
}

/// Single-stream inference latency on T4, seconds.
pub fn latency_s(kind: ModelKind) -> f64 {
    let flops = 2.0 * model(kind).total_macs as f64;
    flops / (PEAK_FP16_OPS * achieved_utilization(kind))
}

/// Throughput in frames per second.
pub fn fps(kind: ModelKind) -> f64 {
    1.0 / latency_s(kind)
}

/// Energy per inference, joules (TDP x latency — GPUs idle poorly under
/// single-stream inference, so TDP is the right operating point).
pub fn energy_j(kind: ModelKind) -> f64 {
    TDP_W * latency_s(kind)
}

/// T4 board TOPS/W at the achieved operating point for a workload mix.
pub fn tops_per_watt(kind: ModelKind) -> f64 {
    (PEAK_FP16_OPS * achieved_utilization(kind)) / TDP_W / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ALL_MODELS;

    #[test]
    fn t4_fps_is_gpu_scale() {
        // Published T4 detector numbers: tens to a few hundred FPS.
        for m in ALL_MODELS {
            let f = fps(m);
            assert!((50.0..600.0).contains(&f), "{m:?}: {f} FPS");
        }
    }

    #[test]
    fn t4_slower_than_best_hmai_core_aggregate() {
        // One T4 must not beat the 11-core HMAI on any network (else the
        // paper's Fig. 10 speedup could not hold).
        use crate::accel::{cost, ALL_ACCELS};
        for m in ALL_MODELS {
            let hmai_agg: f64 = ALL_ACCELS
                .iter()
                .map(|&a| cost(a, m).fps())
                .sum::<f64>()
                / 3.0
                * 11.0;
            assert!(hmai_agg > 2.0 * fps(m), "{m:?}: hmai={hmai_agg} t4={}", fps(m));
        }
    }

    #[test]
    fn energy_per_frame_sane() {
        for m in ALL_MODELS {
            let e = energy_j(m);
            assert!((0.05..5.0).contains(&e), "{m:?}: {e} J");
        }
    }
}
