//! 12 nm energy table (per 16-bit access / operation) and the energy
//! aggregation over a layer's access counts.
//!
//! The paper synthesizes at TSMC 12 nm (Synopsys DC + PrimeTime PX); we use
//! per-access energies interpolated from the published 45 nm numbers of
//! Horowitz (ISSCC'14) scaled to 12 nm, the same methodology Eyeriss-class
//! papers use.  Values are in picojoules.

use super::LayerCost;

/// Energy per MAC operation (16-bit multiply-add at 12 nm).
pub const E_MAC_PJ: f64 = 0.9;
/// Energy per PE-local / centralized register access.
pub const E_REG_PJ: f64 = 0.15;
/// Energy per on-chip SRAM buffer (OCB) access.
pub const E_OCB_PJ: f64 = 2.4;
/// Energy per external memory (EXMC, LPDDR-class) access.
pub const E_EXMC_PJ: f64 = 80.0;
/// Static/leakage + clock overhead as a fraction of dynamic energy.
pub const STATIC_OVERHEAD: f64 = 0.15;
/// Idle power of a provisioned-but-idle accelerator as a fraction of its
/// mean busy power: the clock tree and SRAM leakage keep burning when the
/// dataflow stalls (no per-core power gating in the HMAI SoC).  This is
/// why resource-utilization balance is an energy lever (§8.3: higher
/// R_Balance "can decrease the waste of the hardware resources and improve
/// the vehicle's endurance").
pub const IDLE_FRAC: f64 = 0.4;

/// Idle power (W) of one provisioned accelerator of `kind`.
pub fn idle_power_w(kind: crate::accel::AccelKind) -> f64 {
    let mean_busy = crate::workload::ALL_MODELS
        .iter()
        .map(|&m| crate::accel::cost(kind, m).power_w())
        .sum::<f64>()
        / crate::workload::ALL_MODELS.len() as f64;
    IDLE_FRAC * mean_busy
}

/// Total energy of an aggregated `LayerCost`, in joules.
pub fn layer_energy_j(c: &LayerCost) -> f64 {
    let dynamic_pj = c.macs * E_MAC_PJ
        + c.reg_accesses * E_REG_PJ
        + c.ocb_accesses * E_OCB_PJ
        + c.exmc_accesses * E_EXMC_PJ;
    dynamic_pj * (1.0 + STATIC_OVERHEAD) * 1e-12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{cost, task_cost, AccelKind, ALL_ACCELS};
    use crate::workload::{ModelKind, ALL_MODELS};

    #[test]
    fn memory_hierarchy_energy_ordering() {
        // The canonical pyramid: reg < OCB < EXMC.
        assert!(E_REG_PJ < E_OCB_PJ);
        assert!(E_OCB_PJ < E_EXMC_PJ);
    }

    #[test]
    fn per_task_energy_is_millijoule_scale() {
        // A 10-30 GMAC network at a few pJ/MAC-equivalent system energy
        // should land in the 20-500 mJ band — the scale the paper's Fig. 2
        // energy bars imply for per-frame processing.
        for a in ALL_ACCELS {
            for m in ALL_MODELS {
                let e = cost(a, m).energy_j;
                assert!((0.005..1.0).contains(&e), "{a:?} {m:?}: {e} J");
            }
        }
    }

    #[test]
    fn accelerator_power_is_accelerator_scale() {
        // Per-accelerator average power must be single-digit-to-tens of
        // watts (the paper's HMAI draws ~2x a 70 W T4 for 11 cores).
        for a in ALL_ACCELS {
            for m in ALL_MODELS {
                let p = task_cost(a, m).power_w();
                assert!((1.0..40.0).contains(&p), "{a:?} {m:?}: {p} W");
            }
        }
    }

    #[test]
    fn goturn_cheapest_on_mconv() {
        // MconvMC's OCB staging + native FC makes it the energy pick for
        // GOTURN — consistent with Table 9 routing GOTURN to MM.
        let mm = cost(AccelKind::MconvMC, ModelKind::Goturn).energy_j;
        let so = cost(AccelKind::SconvOD, ModelKind::Goturn).energy_j;
        assert!(mm < so * 1.2, "mm={mm} so={so}");
    }
}
