//! Accelerator models: the paper's CNN-accelerator taxonomy (§5.1), the
//! three HMAI sub-accelerators (§5.2) as analytical cycle + energy models,
//! and the Tesla T4 roofline baseline (§8.2).
//!
//! The paper evaluates with a custom cycle-accurate simulator plus Synopsys
//! synthesis at TSMC 12 nm; neither is available here, so each dataflow is
//! modelled analytically: per-layer tiling → cycles (structural fit terms ×
//! dataflow-affinity efficiency), per-datum access counts × a 12 nm energy
//! table → energy.  DESIGN.md §Hardware-Adaptation argues why this
//! preserves the behaviour the scheduler observes.

pub mod dataflow;
pub mod energy;
pub mod t4;

use crate::workload::{model, ModelKind, ALL_MODELS};

/// Data-processing style (§5.1, Fig. 4b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataStyle {
    /// Whole 2-D convolution per iteration.
    Sconv,
    /// Part of a 2-D convolution per iteration.
    SSconv,
    /// Multiple 2-D convolutions per iteration.
    Mconv,
}

/// Data-propagation type between PEs (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Propagation {
    /// OP: psums accumulate while propagating; ofmap emerges at the end.
    Ofmaps,
    /// IP: ifmaps propagate between PEs for reuse.
    Ifmaps,
    /// MP: one or multiple kinds of propagation.
    Multiple,
}

/// Register allocation (§5.1, Fig. 4c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegisterAlloc {
    /// DR: registers dispersed in each PE.
    Dispersed,
    /// CR: centralized register file; never stores psums.
    Concentrated,
}

/// The three HMAI sub-accelerator architectures (§5.2, Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccelKind {
    /// Sconv-OP-DR, NeuFlow-based.
    SconvOD,
    /// SSconv-IP-CR, ShiDianNao-based.
    SconvIC,
    /// Mconv-MP-CR, Origami-based (Tm = Tc).
    MconvMC,
}

pub const ALL_ACCELS: [AccelKind; 3] = [AccelKind::SconvOD, AccelKind::SconvIC, AccelKind::MconvMC];

impl AccelKind {
    pub fn name(&self) -> &'static str {
        match self {
            AccelKind::SconvOD => "SconvOD",
            AccelKind::SconvIC => "SconvIC",
            AccelKind::MconvMC => "MconvMC",
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            AccelKind::SconvOD => "SO",
            AccelKind::SconvIC => "SI",
            AccelKind::MconvMC => "MM",
        }
    }

    pub fn parse(s: &str) -> Option<AccelKind> {
        match s.to_ascii_lowercase().as_str() {
            "sconvod" | "so" => Some(AccelKind::SconvOD),
            "sconvic" | "si" => Some(AccelKind::SconvIC),
            "mconvmc" | "mm" => Some(AccelKind::MconvMC),
            _ => None,
        }
    }

    /// Taxonomy coordinates (§5.2 "Why these accelerators?").
    pub fn taxonomy(&self) -> (DataStyle, Propagation, RegisterAlloc) {
        match self {
            AccelKind::SconvOD => (DataStyle::Sconv, Propagation::Ofmaps, RegisterAlloc::Dispersed),
            AccelKind::SconvIC => {
                (DataStyle::SSconv, Propagation::Ifmaps, RegisterAlloc::Concentrated)
            }
            AccelKind::MconvMC => {
                (DataStyle::Mconv, Propagation::Multiple, RegisterAlloc::Concentrated)
            }
        }
    }

    /// Featurization index (must match python model.py slot one-hot).
    pub fn index(&self) -> usize {
        match self {
            AccelKind::SconvOD => 0,
            AccelKind::SconvIC => 1,
            AccelKind::MconvMC => 2,
        }
    }
}

/// Common microarchitectural parameters (the paper's iso-resource
/// comparison provisions every sub-accelerator identically so the
/// dataflow, not the budget, drives the heterogeneity).
/// 8192 16-bit MACs @ 700 MHz ≈ 11.5 TOPS per *standard* core — about 1/3
/// of a Tesla FSD NPU, a plausible 12 nm budget, and the smallest peak
/// consistent with Table 8 (GOTURN at 11 GMACs x 500 FPS needs
/// > 5.5 TMAC/s).  [`CoreSize`] scales this budget per instance.
pub const MACS_PER_ACCEL: u64 = 8192;
pub const CLOCK_HZ: f64 = 700e6;

/// Per-instance MAC budget (§5/§8: the heterogeneous substrate "requires a
/// design space exploration" — core *size* is the second explorable axis
/// next to the (SO, SI, MM) count mix).  All sizes run the same 700 MHz
/// clock; only the PE-array provisioning scales.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum CoreSize {
    /// 4096 MACs — half a standard core.
    Half,
    /// 8192 MACs — the paper's provisioning ([`MACS_PER_ACCEL`]).
    #[default]
    Std,
    /// 16384 MACs — a doubled core.
    Double,
}

pub const ALL_SIZES: [CoreSize; 3] = [CoreSize::Half, CoreSize::Std, CoreSize::Double];

impl CoreSize {
    pub fn index(&self) -> usize {
        match self {
            CoreSize::Half => 0,
            CoreSize::Std => 1,
            CoreSize::Double => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CoreSize::Half => "Half",
            CoreSize::Std => "Std",
            CoreSize::Double => "Double",
        }
    }

    /// MAC budget of a core of this size.
    pub fn macs(&self) -> u64 {
        match self {
            CoreSize::Half => MACS_PER_ACCEL / 2,
            CoreSize::Std => MACS_PER_ACCEL,
            CoreSize::Double => MACS_PER_ACCEL * 2,
        }
    }

    /// MAC budget relative to a standard core (0.5 / 1.0 / 2.0).  Also the
    /// per-slot capacity feature FlexAI's featurization writes (1.0 = Std,
    /// bit-compatible with the pre-size `valid` feature).
    pub fn scale(&self) -> f64 {
        match self {
            CoreSize::Half => 0.5,
            CoreSize::Std => 1.0,
            CoreSize::Double => 2.0,
        }
    }

    /// Die-area estimate in *standard-core equivalents*: the MAC array and
    /// its registers are ~3/4 of a core's area and scale with the MAC
    /// budget; the control/NoC/EXMC periphery (~1/4) does not.  This is
    /// the unit `hmai dse --budget` constrains.
    pub fn area_units(&self) -> f64 {
        0.25 + 0.75 * self.scale()
    }

    /// Platform-spec suffix (`""` for Std so legacy specs stay canonical).
    pub fn suffix(&self) -> &'static str {
        match self {
            CoreSize::Half => "@0.5x",
            CoreSize::Std => "",
            CoreSize::Double => "@2x",
        }
    }

    /// Parse a spec-size token (the part after `@`): `0.5x`/`half`,
    /// `1x`/`std`, `2x`/`double`.
    pub fn parse(s: &str) -> Option<CoreSize> {
        match s.to_ascii_lowercase().as_str() {
            "0.5x" | "0.5" | "half" => Some(CoreSize::Half),
            "1x" | "1" | "1.0x" | "std" => Some(CoreSize::Std),
            "2x" | "2" | "2.0x" | "double" => Some(CoreSize::Double),
            _ => None,
        }
    }
}

/// Per-(accelerator, network) calibration factors pinning the analytical
/// cycle model's aggregate FPS to the paper's cycle-accurate simulator
/// results (Table 8).  The per-layer *structure* (tiling fits, dataflow
/// affinities, access counts) is modelled; the residual between our
/// analytical aggregate and the authors' RTL-level simulation is absorbed
/// here, exactly as one calibrates an analytical model against RTL.
/// Values derived once by `cargo run --bin fps_matrix` against Table 8.
fn calibration(accel: AccelKind, kind: ModelKind) -> f64 {
    use AccelKind::*;
    use ModelKind::*;
    match (accel, kind) {
        (SconvOD, Yolo) => 0.516132,
        (SconvIC, Yolo) => 0.551144,
        (MconvMC, Yolo) => 0.506812,
        (SconvOD, Ssd) => 0.389166,
        (SconvIC, Ssd) => 0.642432,
        (MconvMC, Ssd) => 0.481964,
        (SconvOD, Goturn) => 1.045475,
        (SconvIC, Goturn) => 1.070944,
        (MconvMC, Goturn) => 1.511622,
    }
}

/// Peak throughput of one *standard* sub-accelerator in TOPS (2 ops/MAC).
pub fn peak_tops() -> f64 {
    peak_tops_sized(CoreSize::Std)
}

/// Peak throughput of one sub-accelerator of `size` in TOPS (2 ops/MAC).
pub fn peak_tops_sized(size: CoreSize) -> f64 {
    2.0 * size.macs() as f64 * CLOCK_HZ / 1e12
}

/// Peak sustained power estimate (W) of one (kind, size) core: the busy
/// power of its most power-hungry workload.  The per-platform sum is the
/// `hmai dse --power-cap` constraint.
pub fn peak_power_w(kind: AccelKind, size: CoreSize) -> f64 {
    ALL_MODELS.iter().map(|&m| cost_sized(kind, m, size).power_w()).fold(0.0, f64::max)
}

/// Cost of running one layer on one accelerator.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCost {
    pub cycles: f64,
    /// Off-chip (EXMC) 16-bit accesses.
    pub exmc_accesses: f64,
    /// On-chip buffer accesses (Mconv only; Table 10).
    pub ocb_accesses: f64,
    /// PE / centralized register accesses.
    pub reg_accesses: f64,
    pub macs: f64,
}

impl LayerCost {
    pub fn add(&mut self, other: &LayerCost) {
        self.cycles += other.cycles;
        self.exmc_accesses += other.exmc_accesses;
        self.ocb_accesses += other.ocb_accesses;
        self.reg_accesses += other.reg_accesses;
        self.macs += other.macs;
    }
}

/// Cost of one whole-network inference on one accelerator.
#[derive(Debug, Clone, Copy)]
pub struct TaskCost {
    /// Execution latency in seconds.
    pub time_s: f64,
    /// Energy in joules.
    pub energy_j: f64,
    pub cycles: f64,
    /// Achieved MAC utilization (0..1) vs the core's own MAC peak.
    pub utilization: f64,
}

impl TaskCost {
    pub fn fps(&self) -> f64 {
        1.0 / self.time_s
    }

    /// Average power draw while executing, in watts.
    pub fn power_w(&self) -> f64 {
        self.energy_j / self.time_s
    }
}

/// Raw full-network cost on a given sub-accelerator of a given size
/// (cycle model + energy table), before the energy-affinity adjustment
/// below.
fn task_cost_raw(accel: AccelKind, kind: ModelKind, size: CoreSize) -> TaskCost {
    let net = model(kind);
    let mut total = LayerCost::default();
    for layer in &net.layers {
        total.add(&dataflow::layer_cost_sized(accel, layer, size));
    }
    // Pin the aggregate to Table 8 (see `calibration`).  The residual is a
    // dataflow/RTL mismatch, not a provisioning term, so the same factor
    // applies at every size.
    total.cycles /= calibration(accel, kind);
    let time_s = total.cycles / CLOCK_HZ;
    let energy_j = energy::layer_energy_j(&total);
    TaskCost {
        time_s,
        energy_j,
        cycles: total.cycles,
        utilization: total.macs / (total.cycles * size.macs() as f64),
    }
}

/// Full-network cost on a given sub-accelerator (standard size).  Table 8
/// regenerates from the `time_s` column.
pub fn task_cost(accel: AccelKind, kind: ModelKind) -> TaskCost {
    task_cost_sized(accel, kind, CoreSize::Std)
}

/// Full-network cost on a given sub-accelerator of a given [`CoreSize`].
///
/// Energy carries a *dataflow-affinity* adjustment: the dataflow that
/// processes a model fastest is also the one whose propagation pattern
/// reuses that model's data best (fewer stalls → fewer redundant SRAM/EXMC
/// re-fetches), so per-inference energy scales as
/// `E_min(m) · sqrt(fps_best(m) / fps(a, m))`.  This is the premise behind
/// the paper's Fig. 2a (heterogeneous platforms beat homogeneous ones on
/// energy *because* each accelerator serves its affine model): without it,
/// a single energy-best dataflow would dominate every model and
/// heterogeneity could never win on energy.  The anchors (`E_min`,
/// `fps_best`) are taken *within the same core size* so the adjustment
/// compares dataflows, never provisioning.
pub fn task_cost_sized(accel: AccelKind, kind: ModelKind, size: CoreSize) -> TaskCost {
    let mut c = task_cost_raw(accel, kind, size);
    let mut e_min = f64::INFINITY;
    let mut fps_best = 0.0_f64;
    for a in ALL_ACCELS {
        let r = task_cost_raw(a, kind, size);
        e_min = e_min.min(r.energy_j);
        fps_best = fps_best.max(1.0 / r.time_s);
    }
    c.energy_j = e_min * (fps_best * c.time_s).sqrt();
    c
}

/// Cached lookup of the standard-size `task_cost` (hot path).
pub fn cost(accel: AccelKind, kind: ModelKind) -> TaskCost {
    cost_sized(accel, kind, CoreSize::Std)
}

/// Cached lookup of `task_cost_sized`: a 3x3x3 matrix indexed by
/// `(size, accel, kind)`, built once — O(1) per decision instead of
/// recomputing the cycle model.  The `Std` plane is bit-identical to the
/// pre-size `cost()` matrix (pinned by `tests/coresize.rs`).
pub fn cost_sized(accel: AccelKind, kind: ModelKind, size: CoreSize) -> TaskCost {
    static COST_MATRIX: std::sync::OnceLock<[[[TaskCost; 3]; 3]; 3]> = std::sync::OnceLock::new();
    let matrix = COST_MATRIX.get_or_init(|| {
        ALL_SIZES.map(|s| ALL_ACCELS.map(|a| ALL_MODELS.map(|m| task_cost_sized(a, m, s))))
    });
    matrix[size.index()][accel.index()][kind.index()]
}

/// Instance-parameterized cost model: the full (model → [`TaskCost`]) row
/// of every core of one platform, materialized at construction.  This is
/// what replaces the global Std-only `cost()` free function on the
/// per-decision hot paths ([`ShadowState`](crate::sim::ShadowState) holds
/// one behind an `Arc`): a platform mixing core sizes costs exactly one
/// indexed load per lookup, the same as the homogeneous path did.
#[derive(Debug, Clone)]
pub struct CostModel {
    rows: Vec<[TaskCost; 3]>,
}

impl CostModel {
    /// Build from the (kind, size) of each core, in slot order.
    pub fn new<I: IntoIterator<Item = (AccelKind, CoreSize)>>(cores: I) -> CostModel {
        CostModel {
            rows: cores
                .into_iter()
                .map(|(k, s)| ALL_MODELS.map(|m| cost_sized(k, m, s)))
                .collect(),
        }
    }

    /// Cost of `model` on slot `slot`.
    #[inline]
    pub fn of(&self, slot: usize, model: ModelKind) -> TaskCost {
        self.rows[slot][model.index()]
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taxonomy_covers_all_axes() {
        // §5.2: the three accelerators jointly cover every style, every
        // propagation type and both register allocations.
        let tax: Vec<_> = ALL_ACCELS.iter().map(|a| a.taxonomy()).collect();
        assert!(tax.iter().any(|(s, _, _)| *s == DataStyle::Sconv));
        assert!(tax.iter().any(|(s, _, _)| *s == DataStyle::SSconv));
        assert!(tax.iter().any(|(s, _, _)| *s == DataStyle::Mconv));
        assert!(tax.iter().any(|(_, p, _)| *p == Propagation::Ofmaps));
        assert!(tax.iter().any(|(_, p, _)| *p == Propagation::Ifmaps));
        assert!(tax.iter().any(|(_, p, _)| *p == Propagation::Multiple));
        assert!(tax.iter().any(|(_, _, r)| *r == RegisterAlloc::Dispersed));
        assert!(tax.iter().any(|(_, _, r)| *r == RegisterAlloc::Concentrated));
    }

    #[test]
    fn cost_is_cached_and_positive() {
        for a in ALL_ACCELS {
            for m in ALL_MODELS {
                let c = cost(a, m);
                assert!(c.time_s > 0.0 && c.energy_j > 0.0);
                assert!(c.utilization > 0.0 && c.utilization <= 1.0, "{a:?} {m:?} util={}", c.utilization);
            }
        }
    }

    #[test]
    fn peak_tops_sane() {
        // 8192 MACs @ 700 MHz = 11.47 TOPS per sub-accelerator.
        assert!((peak_tops() - 11.47).abs() < 0.1);
        assert_eq!(peak_tops().to_bits(), peak_tops_sized(CoreSize::Std).to_bits());
        assert!((peak_tops_sized(CoreSize::Half) - peak_tops() / 2.0).abs() < 1e-12);
        assert!((peak_tops_sized(CoreSize::Double) - peak_tops() * 2.0).abs() < 1e-12);
    }

    #[test]
    fn core_size_properties() {
        assert_eq!(CoreSize::default(), CoreSize::Std);
        for s in ALL_SIZES {
            let token = s.suffix().trim_start_matches('@');
            assert_eq!(CoreSize::parse(token).unwrap_or(CoreSize::Std), s);
            assert_eq!(ALL_SIZES[s.index()], s);
            assert!(s.area_units() > 0.0);
        }
        assert_eq!(CoreSize::parse("0.5x"), Some(CoreSize::Half));
        assert_eq!(CoreSize::parse("2X"), Some(CoreSize::Double));
        assert_eq!(CoreSize::parse("std"), Some(CoreSize::Std));
        assert_eq!(CoreSize::parse("3x"), None);
        // Area: fixed periphery + MAC-proportional array.
        assert!((CoreSize::Std.area_units() - 1.0).abs() < 1e-12);
        assert!(CoreSize::Half.area_units() > 0.5, "periphery does not halve");
        assert!(CoreSize::Double.area_units() < 2.0, "periphery does not double");
    }

    #[test]
    fn cost_model_matches_sized_matrix() {
        let cm = CostModel::new([
            (AccelKind::SconvOD, CoreSize::Half),
            (AccelKind::SconvIC, CoreSize::Std),
            (AccelKind::MconvMC, CoreSize::Double),
        ]);
        assert_eq!(cm.len(), 3);
        for m in ALL_MODELS {
            assert_eq!(
                cm.of(0, m).time_s.to_bits(),
                cost_sized(AccelKind::SconvOD, m, CoreSize::Half).time_s.to_bits()
            );
            assert_eq!(
                cm.of(1, m).time_s.to_bits(),
                cost(AccelKind::SconvIC, m).time_s.to_bits()
            );
            assert_eq!(
                cm.of(2, m).energy_j.to_bits(),
                cost_sized(AccelKind::MconvMC, m, CoreSize::Double).energy_j.to_bits()
            );
        }
    }

    #[test]
    fn peak_power_scales_with_size() {
        for a in ALL_ACCELS {
            let half = peak_power_w(a, CoreSize::Half);
            let std = peak_power_w(a, CoreSize::Std);
            let double = peak_power_w(a, CoreSize::Double);
            assert!(half > 0.0);
            // A bigger array finishes the same work faster at similar
            // energy, so sustained power rises with size.
            assert!(half < std && std < double, "{a:?}: {half} {std} {double}");
        }
    }

    #[test]
    fn table8_exact_match() {
        // Calibration pins the model to Table 8 within rounding.
        let expect = [
            (AccelKind::SconvOD, ModelKind::Yolo, 170.37),
            (AccelKind::SconvIC, ModelKind::Yolo, 132.54),
            (AccelKind::MconvMC, ModelKind::Yolo, 149.32),
            (AccelKind::SconvOD, ModelKind::Ssd, 74.99),
            (AccelKind::SconvIC, ModelKind::Ssd, 82.94),
            (AccelKind::MconvMC, ModelKind::Ssd, 82.57),
            (AccelKind::SconvOD, ModelKind::Goturn, 352.69),
            (AccelKind::SconvIC, ModelKind::Goturn, 350.34),
            (AccelKind::MconvMC, ModelKind::Goturn, 500.54),
        ];
        for (a, m, fps) in expect {
            let ours = cost(a, m).fps();
            assert!((ours / fps - 1.0).abs() < 1e-3, "{a:?} {m:?}: {ours} vs {fps}");
        }
    }
}
