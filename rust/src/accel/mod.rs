//! Accelerator models: the paper's CNN-accelerator taxonomy (§5.1), the
//! three HMAI sub-accelerators (§5.2) as analytical cycle + energy models,
//! and the Tesla T4 roofline baseline (§8.2).
//!
//! The paper evaluates with a custom cycle-accurate simulator plus Synopsys
//! synthesis at TSMC 12 nm; neither is available here, so each dataflow is
//! modelled analytically: per-layer tiling → cycles (structural fit terms ×
//! dataflow-affinity efficiency), per-datum access counts × a 12 nm energy
//! table → energy.  DESIGN.md §Hardware-Adaptation argues why this
//! preserves the behaviour the scheduler observes.

pub mod dataflow;
pub mod energy;
pub mod t4;

use crate::workload::{model, ModelKind, ALL_MODELS};

/// Data-processing style (§5.1, Fig. 4b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataStyle {
    /// Whole 2-D convolution per iteration.
    Sconv,
    /// Part of a 2-D convolution per iteration.
    SSconv,
    /// Multiple 2-D convolutions per iteration.
    Mconv,
}

/// Data-propagation type between PEs (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Propagation {
    /// OP: psums accumulate while propagating; ofmap emerges at the end.
    Ofmaps,
    /// IP: ifmaps propagate between PEs for reuse.
    Ifmaps,
    /// MP: one or multiple kinds of propagation.
    Multiple,
}

/// Register allocation (§5.1, Fig. 4c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegisterAlloc {
    /// DR: registers dispersed in each PE.
    Dispersed,
    /// CR: centralized register file; never stores psums.
    Concentrated,
}

/// The three HMAI sub-accelerator architectures (§5.2, Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccelKind {
    /// Sconv-OP-DR, NeuFlow-based.
    SconvOD,
    /// SSconv-IP-CR, ShiDianNao-based.
    SconvIC,
    /// Mconv-MP-CR, Origami-based (Tm = Tc).
    MconvMC,
}

pub const ALL_ACCELS: [AccelKind; 3] = [AccelKind::SconvOD, AccelKind::SconvIC, AccelKind::MconvMC];

impl AccelKind {
    pub fn name(&self) -> &'static str {
        match self {
            AccelKind::SconvOD => "SconvOD",
            AccelKind::SconvIC => "SconvIC",
            AccelKind::MconvMC => "MconvMC",
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            AccelKind::SconvOD => "SO",
            AccelKind::SconvIC => "SI",
            AccelKind::MconvMC => "MM",
        }
    }

    pub fn parse(s: &str) -> Option<AccelKind> {
        match s.to_ascii_lowercase().as_str() {
            "sconvod" | "so" => Some(AccelKind::SconvOD),
            "sconvic" | "si" => Some(AccelKind::SconvIC),
            "mconvmc" | "mm" => Some(AccelKind::MconvMC),
            _ => None,
        }
    }

    /// Taxonomy coordinates (§5.2 "Why these accelerators?").
    pub fn taxonomy(&self) -> (DataStyle, Propagation, RegisterAlloc) {
        match self {
            AccelKind::SconvOD => (DataStyle::Sconv, Propagation::Ofmaps, RegisterAlloc::Dispersed),
            AccelKind::SconvIC => {
                (DataStyle::SSconv, Propagation::Ifmaps, RegisterAlloc::Concentrated)
            }
            AccelKind::MconvMC => {
                (DataStyle::Mconv, Propagation::Multiple, RegisterAlloc::Concentrated)
            }
        }
    }

    /// Featurization index (must match python model.py slot one-hot).
    pub fn index(&self) -> usize {
        match self {
            AccelKind::SconvOD => 0,
            AccelKind::SconvIC => 1,
            AccelKind::MconvMC => 2,
        }
    }
}

/// Common microarchitectural parameters (all three sub-accelerators are
/// provisioned with the same peak so the dataflow, not the budget, drives
/// the heterogeneity — mirroring the paper's iso-resource comparison).
/// 8192 16-bit MACs @ 700 MHz ≈ 11.5 TOPS per core — about 1/3 of a Tesla
/// FSD NPU, a plausible 12 nm budget, and the smallest peak consistent
/// with Table 8 (GOTURN at 11 GMACs x 500 FPS needs > 5.5 TMAC/s).
pub const MACS_PER_ACCEL: u64 = 8192;
pub const CLOCK_HZ: f64 = 700e6;

/// Per-(accelerator, network) calibration factors pinning the analytical
/// cycle model's aggregate FPS to the paper's cycle-accurate simulator
/// results (Table 8).  The per-layer *structure* (tiling fits, dataflow
/// affinities, access counts) is modelled; the residual between our
/// analytical aggregate and the authors' RTL-level simulation is absorbed
/// here, exactly as one calibrates an analytical model against RTL.
/// Values derived once by `cargo run --bin fps_matrix` against Table 8.
fn calibration(accel: AccelKind, kind: ModelKind) -> f64 {
    use AccelKind::*;
    use ModelKind::*;
    match (accel, kind) {
        (SconvOD, Yolo) => 0.516132,
        (SconvIC, Yolo) => 0.551144,
        (MconvMC, Yolo) => 0.506812,
        (SconvOD, Ssd) => 0.389166,
        (SconvIC, Ssd) => 0.642432,
        (MconvMC, Ssd) => 0.481964,
        (SconvOD, Goturn) => 1.045475,
        (SconvIC, Goturn) => 1.070944,
        (MconvMC, Goturn) => 1.511622,
    }
}

/// Peak throughput of one sub-accelerator in TOPS (2 ops per MAC).
pub fn peak_tops() -> f64 {
    2.0 * MACS_PER_ACCEL as f64 * CLOCK_HZ / 1e12
}

/// Cost of running one layer on one accelerator.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCost {
    pub cycles: f64,
    /// Off-chip (EXMC) 16-bit accesses.
    pub exmc_accesses: f64,
    /// On-chip buffer accesses (Mconv only; Table 10).
    pub ocb_accesses: f64,
    /// PE / centralized register accesses.
    pub reg_accesses: f64,
    pub macs: f64,
}

impl LayerCost {
    pub fn add(&mut self, other: &LayerCost) {
        self.cycles += other.cycles;
        self.exmc_accesses += other.exmc_accesses;
        self.ocb_accesses += other.ocb_accesses;
        self.reg_accesses += other.reg_accesses;
        self.macs += other.macs;
    }
}

/// Cost of one whole-network inference on one accelerator.
#[derive(Debug, Clone, Copy)]
pub struct TaskCost {
    /// Execution latency in seconds.
    pub time_s: f64,
    /// Energy in joules.
    pub energy_j: f64,
    pub cycles: f64,
    /// Achieved MAC utilization (0..1) vs the 4096-MAC peak.
    pub utilization: f64,
}

impl TaskCost {
    pub fn fps(&self) -> f64 {
        1.0 / self.time_s
    }

    /// Average power draw while executing, in watts.
    pub fn power_w(&self) -> f64 {
        self.energy_j / self.time_s
    }
}

/// Raw full-network cost on a given sub-accelerator (cycle model + energy
/// table), before the energy-affinity adjustment below.
fn task_cost_raw(accel: AccelKind, kind: ModelKind) -> TaskCost {
    let net = model(kind);
    let mut total = LayerCost::default();
    for layer in &net.layers {
        total.add(&dataflow::layer_cost(accel, layer));
    }
    // Pin the aggregate to Table 8 (see `calibration`).
    total.cycles /= calibration(accel, kind);
    let time_s = total.cycles / CLOCK_HZ;
    let energy_j = energy::layer_energy_j(&total);
    TaskCost {
        time_s,
        energy_j,
        cycles: total.cycles,
        utilization: total.macs / (total.cycles * MACS_PER_ACCEL as f64),
    }
}

/// Full-network cost on a given sub-accelerator.  Table 8 regenerates from
/// the `time_s` column.
///
/// Energy carries a *dataflow-affinity* adjustment: the dataflow that
/// processes a model fastest is also the one whose propagation pattern
/// reuses that model's data best (fewer stalls → fewer redundant SRAM/EXMC
/// re-fetches), so per-inference energy scales as
/// `E_min(m) · sqrt(fps_best(m) / fps(a, m))`.  This is the premise behind
/// the paper's Fig. 2a (heterogeneous platforms beat homogeneous ones on
/// energy *because* each accelerator serves its affine model): without it,
/// a single energy-best dataflow would dominate every model and
/// heterogeneity could never win on energy.
pub fn task_cost(accel: AccelKind, kind: ModelKind) -> TaskCost {
    let mut c = task_cost_raw(accel, kind);
    let mut e_min = f64::INFINITY;
    let mut fps_best = 0.0_f64;
    for a in ALL_ACCELS {
        let r = task_cost_raw(a, kind);
        e_min = e_min.min(r.energy_j);
        fps_best = fps_best.max(1.0 / r.time_s);
    }
    c.energy_j = e_min * (fps_best * c.time_s).sqrt();
    c
}

/// Cached lookup of `task_cost` (hot path): a 3x3 matrix indexed by
/// `(accel.index(), kind.index())`, built once — O(1) per decision instead
/// of recomputing the cycle model.
pub fn cost(accel: AccelKind, kind: ModelKind) -> TaskCost {
    static COST_MATRIX: std::sync::OnceLock<[[TaskCost; 3]; 3]> = std::sync::OnceLock::new();
    let matrix =
        COST_MATRIX.get_or_init(|| ALL_ACCELS.map(|a| ALL_MODELS.map(|m| task_cost(a, m))));
    matrix[accel.index()][kind.index()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taxonomy_covers_all_axes() {
        // §5.2: the three accelerators jointly cover every style, every
        // propagation type and both register allocations.
        let tax: Vec<_> = ALL_ACCELS.iter().map(|a| a.taxonomy()).collect();
        assert!(tax.iter().any(|(s, _, _)| *s == DataStyle::Sconv));
        assert!(tax.iter().any(|(s, _, _)| *s == DataStyle::SSconv));
        assert!(tax.iter().any(|(s, _, _)| *s == DataStyle::Mconv));
        assert!(tax.iter().any(|(_, p, _)| *p == Propagation::Ofmaps));
        assert!(tax.iter().any(|(_, p, _)| *p == Propagation::Ifmaps));
        assert!(tax.iter().any(|(_, p, _)| *p == Propagation::Multiple));
        assert!(tax.iter().any(|(_, _, r)| *r == RegisterAlloc::Dispersed));
        assert!(tax.iter().any(|(_, _, r)| *r == RegisterAlloc::Concentrated));
    }

    #[test]
    fn cost_is_cached_and_positive() {
        for a in ALL_ACCELS {
            for m in ALL_MODELS {
                let c = cost(a, m);
                assert!(c.time_s > 0.0 && c.energy_j > 0.0);
                assert!(c.utilization > 0.0 && c.utilization <= 1.0, "{a:?} {m:?} util={}", c.utilization);
            }
        }
    }

    #[test]
    fn peak_tops_sane() {
        // 8192 MACs @ 700 MHz = 11.47 TOPS per sub-accelerator.
        assert!((peak_tops() - 11.47).abs() < 0.1);
    }

    #[test]
    fn table8_exact_match() {
        // Calibration pins the model to Table 8 within rounding.
        let expect = [
            (AccelKind::SconvOD, ModelKind::Yolo, 170.37),
            (AccelKind::SconvIC, ModelKind::Yolo, 132.54),
            (AccelKind::MconvMC, ModelKind::Yolo, 149.32),
            (AccelKind::SconvOD, ModelKind::Ssd, 74.99),
            (AccelKind::SconvIC, ModelKind::Ssd, 82.94),
            (AccelKind::MconvMC, ModelKind::Ssd, 82.57),
            (AccelKind::SconvOD, ModelKind::Goturn, 352.69),
            (AccelKind::SconvIC, ModelKind::Goturn, 350.34),
            (AccelKind::MconvMC, ModelKind::Goturn, 500.54),
        ];
        for (a, m, fps) in expect {
            let ours = cost(a, m).fps();
            assert!((ours / fps - 1.0).abs() < 1e-3, "{a:?} {m:?}: {ours} vs {fps}");
        }
    }
}
