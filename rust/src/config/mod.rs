//! Typed experiment configuration: JSON files + CLI overrides → one struct
//! every entry point (CLI subcommands, examples, benches) consumes.
//!
//! Precedence: defaults < `--config file.json` < individual `--key` flags.

use std::path::Path;

use anyhow::{Context, Result};

use crate::env::taskgen::DeadlineMode;
use crate::env::Area;
use crate::plan::ExperimentPlan;
use crate::platform::Platform;
use crate::sched::flexai::epsilon::EpsilonSchedule;
use crate::sched::flexai::FlexAIConfig;
use crate::sched::SchedulerSpec;
use crate::util::cli::Args;
use crate::util::json::{Json, JsonObj};

/// Route/queue generation settings.
#[derive(Debug, Clone, PartialEq)]
pub struct EnvConfig {
    pub area: Area,
    /// Route distances in meters; one queue per entry (§8.2/8.3 use five
    /// 1-2 km routes).
    pub distances_m: Vec<f64>,
    pub seed: u64,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            area: Area::Urban,
            distances_m: vec![1000.0, 1250.0, 1500.0, 1750.0, 2000.0],
            seed: 42,
        }
    }
}

/// Training-loop settings (examples/train_flexai, `hmai train`).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Episodes = task queues (§8.3: "each episode includes one task
    /// queue").
    pub episodes: usize,
    /// Route length per training episode (m).  Shorter than eval routes to
    /// keep wall-clock sane; the loss converges within 2-4 episodes
    /// (Fig. 11).
    pub episode_distance_m: f64,
    /// Checkpoint output path.
    pub checkpoint: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            episodes: 3,
            episode_distance_m: 300.0,
            checkpoint: "flexai_ckpt.json".into(),
        }
    }
}

/// The top-level experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Platform spec: "hmai", "13so", "13si", "12mm" or "so,si,mm" counts.
    /// May carry an inline `+<topology>` suffix (`"hmai+mesh2x2"`).
    pub platform: String,
    /// Package topology suffix applied to `platform` (empty = whatever the
    /// platform spec says; `"mono"` forces monolithic).  CLI: `--topology`.
    pub topology: String,
    /// Scheduler name ("flexai" or a baseline).
    pub scheduler: String,
    /// FlexAI checkpoint to load (empty = fresh init).
    pub checkpoint: String,
    /// Deadline regime for generated task queues.
    pub deadline: DeadlineMode,
    /// Scenario-library archetype names to sweep (empty = the plain
    /// area/distance axis).  CLI: `--scenario <name[,name...]|all>`.
    pub scenarios: Vec<String>,
    /// Apply scenario-declared platform events (accelerator failure /
    /// recovery / derating) to each trial's simulation.  CLI: `--events`.
    pub events: bool,
    /// Engine worker threads (0 = all cores, 1 = sequential).
    pub jobs: usize,
    /// Seed replicates per sweep cell (1 = just the base seed; > 1 expands
    /// the seed axis via `plan::replicate_seeds`).  CLI: `--replicates`.
    pub replicates: usize,
    pub env: EnvConfig,
    pub train: TrainConfig,
    pub flexai: FlexAIConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            platform: "hmai".into(),
            topology: String::new(),
            scheduler: "flexai".into(),
            checkpoint: String::new(),
            deadline: DeadlineMode::Rss,
            scenarios: Vec::new(),
            events: false,
            jobs: 1,
            replicates: 1,
            env: EnvConfig::default(),
            train: TrainConfig::default(),
            flexai: FlexAIConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// The platform spec with the configured `--topology` suffix applied
    /// (`"hmai"` + `"mesh2x2"` → `"hmai+mesh2x2"`).
    pub fn platform_spec(&self) -> String {
        if self.topology.is_empty() {
            self.platform.clone()
        } else {
            format!("{}+{}", self.platform, self.topology)
        }
    }

    /// Resolve the platform spec (descriptive errors via `try_parse`, so a
    /// bad `--platform`/`--topology` string explains itself).
    pub fn platform(&self) -> Result<Platform> {
        Platform::try_parse(&self.platform_spec())
            .map_err(|e| anyhow::anyhow!("--platform: {e}"))
    }

    /// Resolve the scheduler name into a typed spec (FlexAI carries the
    /// configured checkpoint).
    pub fn scheduler_spec(&self) -> Result<SchedulerSpec> {
        let spec = SchedulerSpec::parse(&self.scheduler)?;
        Ok(match spec {
            SchedulerSpec::FlexAI { .. } => SchedulerSpec::FlexAI {
                checkpoint: if self.checkpoint.is_empty() {
                    None
                } else {
                    Some(self.checkpoint.clone())
                },
            },
            other => other,
        })
    }

    /// The single-scheduler/single-platform sweep this config describes:
    /// the configured area (or scenario-library archetypes), distance
    /// list, deadline regime and seed.
    pub fn plan(&self) -> Result<ExperimentPlan> {
        let mut plan = ExperimentPlan::new()
            .area(self.env.area)
            .distances(self.env.distances_m.iter().copied())
            .deadline(self.deadline)
            .platform(self.platform_spec())
            .scheduler(self.scheduler_spec()?)
            .seed(self.env.seed);
        if self.replicates > 1 {
            plan = plan.replicates(self.env.seed, self.replicates);
        }
        if !self.scenarios.is_empty() {
            plan = plan.scenarios(self.scenarios.iter().cloned());
        }
        Ok(plan)
    }

    /// Load from a JSON file.
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<ExperimentConfig> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("config json: {e:?}"))?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_json(&j)?;
        Ok(cfg)
    }

    /// Merge a JSON object over this config (unknown keys rejected so typos
    /// fail loudly).
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let o = j.as_obj().context("config: not an object")?;
        for (k, v) in o.iter() {
            match k {
                "platform" => self.platform = v.as_str().context("platform")?.to_string(),
                "topology" => self.topology = v.as_str().context("topology")?.to_string(),
                "scheduler" => self.scheduler = v.as_str().context("scheduler")?.to_string(),
                "checkpoint" => self.checkpoint = v.as_str().context("checkpoint")?.to_string(),
                "deadline" => {
                    self.deadline = DeadlineMode::parse(v.as_str().context("deadline")?)
                        .context("deadline: expected rss|frame")?
                }
                "jobs" => self.jobs = v.as_usize().context("jobs")?,
                "replicates" => self.replicates = v.as_usize().context("replicates")?,
                "events" => self.events = v.as_bool().context("events")?,
                "scenarios" => {
                    self.scenarios = v
                        .as_arr()
                        .context("scenarios")?
                        .iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect();
                }
                "area" => {
                    self.env.area = Area::parse(v.as_str().context("area")?)
                        .context("area: expected ub|uhw|hw")?
                }
                "distances_m" => {
                    self.env.distances_m = v
                        .as_arr()
                        .context("distances_m")?
                        .iter()
                        .filter_map(|x| x.as_f64())
                        .collect();
                    anyhow::ensure!(!self.env.distances_m.is_empty(), "distances_m empty");
                }
                "seed" => self.env.seed = v.as_f64().context("seed")? as u64,
                "episodes" => self.train.episodes = v.as_usize().context("episodes")?,
                "episode_distance_m" => {
                    self.train.episode_distance_m = v.as_f64().context("episode_distance_m")?
                }
                "train_checkpoint" => {
                    self.train.checkpoint = v.as_str().context("train_checkpoint")?.to_string()
                }
                "epsilon_start" => self.flexai.epsilon.start = v.as_f64().with_context(|| k.to_string())?,
                "epsilon_end" => self.flexai.epsilon.end = v.as_f64().with_context(|| k.to_string())?,
                "epsilon_decay_steps" => {
                    self.flexai.epsilon.decay_steps = v.as_f64().with_context(|| k.to_string())? as u64
                }
                "train_every" => self.flexai.train_every = v.as_f64().with_context(|| k.to_string())? as u64,
                "target_sync_every" => {
                    self.flexai.target_sync_every = v.as_f64().with_context(|| k.to_string())? as u64
                }
                "replay_capacity" => self.flexai.replay_capacity = v.as_usize().with_context(|| k.to_string())?,
                "min_replay" => self.flexai.min_replay = v.as_usize().with_context(|| k.to_string())?,
                "safety_shield" => {
                    self.flexai.safety_shield = v.as_bool().with_context(|| k.to_string())?
                }
                "guided_explore" => {
                    self.flexai.guided_explore = v.as_bool().with_context(|| k.to_string())?
                }
                other => anyhow::bail!("config: unknown key '{other}'"),
            }
        }
        self.flexai.seed = self.env.seed;
        Ok(())
    }

    /// Apply CLI overrides (`--config` first, then flat flags).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(path) = args.get("config") {
            let loaded = Self::load(Path::new(path))?;
            *self = loaded;
        }
        if let Some(p) = args.get("platform") {
            self.platform = p.to_string();
        }
        if let Some(t) = args.get("topology") {
            self.topology = t.to_string();
        }
        if let Some(s) = args.get("sched") {
            self.scheduler = s.to_string();
        }
        if let Some(c) = args.get("ckpt") {
            self.checkpoint = c.to_string();
        }
        if let Some(a) = args.get("area") {
            self.env.area = Area::parse(a).context("--area: expected ub|uhw|hw")?;
        }
        if let Some(d) = args.get("deadline") {
            self.deadline = DeadlineMode::parse(d).context("--deadline: expected rss|frame")?;
        }
        if let Some(s) = args.get("scenario") {
            self.scenarios = if s.eq_ignore_ascii_case("all") {
                crate::env::scenario::names()
            } else {
                s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
            };
            for name in &self.scenarios {
                crate::env::scenario::find(name).context("--scenario")?;
            }
        }
        if args.flag("events") {
            self.events = true;
        }
        self.jobs = args.get_usize("jobs", self.jobs)?;
        self.replicates = args.get_usize("replicates", self.replicates)?;
        anyhow::ensure!(self.replicates > 0, "--replicates must be >= 1");
        // `--distance` is an alias for `--dist`.
        if let Some(d) = args.get("dist").or_else(|| args.get("distance")) {
            self.env.distances_m = d
                .split(',')
                .map(|x| x.trim().parse::<f64>().context("--dist: bad number"))
                .collect::<Result<Vec<_>>>()?;
        }
        self.env.seed = args.get_u64("seed", self.env.seed)?;
        self.train.episodes = args.get_usize("episodes", self.train.episodes)?;
        self.train.episode_distance_m =
            args.get_f64("episode-dist", self.train.episode_distance_m)?;
        if let Some(o) = args.get("out") {
            self.train.checkpoint = o.to_string();
        }
        if args.flag("no-shield") {
            self.flexai.safety_shield = false;
        }
        if args.flag("no-guided") {
            self.flexai.guided_explore = false;
        }
        self.flexai.seed = self.env.seed;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("platform", Json::Str(self.platform.clone()));
        o.insert("topology", Json::Str(self.topology.clone()));
        o.insert("scheduler", Json::Str(self.scheduler.clone()));
        o.insert("checkpoint", Json::Str(self.checkpoint.clone()));
        o.insert("deadline", Json::Str(self.deadline.name().to_string()));
        o.insert("jobs", Json::Num(self.jobs as f64));
        o.insert("replicates", Json::Num(self.replicates as f64));
        o.insert("events", Json::Bool(self.events));
        o.insert(
            "scenarios",
            Json::Arr(self.scenarios.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        o.insert("area", Json::Str(self.env.area.name().to_lowercase()));
        o.insert("distances_m", Json::array_f64(&self.env.distances_m));
        o.insert("seed", Json::Num(self.env.seed as f64));
        o.insert("episodes", Json::Num(self.train.episodes as f64));
        o.insert("episode_distance_m", Json::Num(self.train.episode_distance_m));
        o.insert("train_checkpoint", Json::Str(self.train.checkpoint.clone()));
        o.insert("epsilon_start", Json::Num(self.flexai.epsilon.start));
        o.insert("epsilon_end", Json::Num(self.flexai.epsilon.end));
        o.insert("epsilon_decay_steps", Json::Num(self.flexai.epsilon.decay_steps as f64));
        o.insert("train_every", Json::Num(self.flexai.train_every as f64));
        o.insert("target_sync_every", Json::Num(self.flexai.target_sync_every as f64));
        o.insert("replay_capacity", Json::Num(self.flexai.replay_capacity as f64));
        o.insert("min_replay", Json::Num(self.flexai.min_replay as f64));
        o.insert("safety_shield", Json::Bool(self.flexai.safety_shield));
        o.insert("guided_explore", Json::Bool(self.flexai.guided_explore));
        Json::Obj(o)
    }

    /// FlexAI config with the configured exploration schedule.
    pub fn flexai_config(&self) -> FlexAIConfig {
        self.flexai.clone()
    }

    /// Greedy (inference-only) FlexAI config.
    pub fn flexai_infer_config(&self) -> FlexAIConfig {
        FlexAIConfig { epsilon: EpsilonSchedule::greedy(), ..self.flexai.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_setup() {
        let c = ExperimentConfig::default();
        assert_eq!(c.platform, "hmai");
        assert_eq!(c.env.distances_m.len(), 5); // five task queues (§8.2)
        assert!(c.env.distances_m.iter().all(|&d| (1000.0..=2000.0).contains(&d)));
        assert!(c.platform().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::default();
        c.scheduler = "minmin".into();
        c.env.area = Area::Highway;
        c.flexai.train_every = 9;
        c.flexai.seed = c.env.seed; // derived field, set by apply_json
        let text = c.to_json().to_string();
        let c2 = ExperimentConfig::from_json_text(&text).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_json_text("{\"nope\": 1}").is_err());
    }

    #[test]
    fn args_override() {
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            "--sched sa --area hw --dist 500,600 --seed 7 --episodes 9 --jobs 4 --deadline frame"
                .split_whitespace()
                .map(String::from),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.scheduler, "sa");
        assert_eq!(c.env.area, Area::Highway);
        assert_eq!(c.env.distances_m, vec![500.0, 600.0]);
        assert_eq!(c.env.seed, 7);
        assert_eq!(c.flexai.seed, 7);
        assert_eq!(c.train.episodes, 9);
        assert_eq!(c.jobs, 4);
        assert_eq!(c.deadline, DeadlineMode::FrameBudget);
    }

    #[test]
    fn scheduler_spec_resolves_aliases_and_checkpoints() {
        let mut c = ExperimentConfig::default();
        c.scheduler = "min-min".into();
        assert_eq!(c.scheduler_spec().unwrap(), SchedulerSpec::MinMin);
        c.scheduler = "flexai".into();
        c.checkpoint = "ckpt.json".into();
        assert_eq!(
            c.scheduler_spec().unwrap(),
            SchedulerSpec::FlexAI { checkpoint: Some("ckpt.json".into()) }
        );
        c.scheduler = "bogus".into();
        assert!(c.scheduler_spec().is_err());
    }

    #[test]
    fn plan_reflects_config() {
        let mut c = ExperimentConfig::default();
        c.scheduler = "sa".into();
        c.env.distances_m = vec![100.0, 200.0];
        let plan = c.plan().unwrap();
        let trials = plan.trials().unwrap();
        assert_eq!(trials.len(), 2);
        assert_eq!(trials[0].scheduler, SchedulerSpec::Sa);
        assert_eq!(trials[0].seed, c.env.seed);
    }

    #[test]
    fn scenario_flag_expands_and_validates() {
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            "--scenario urban-rush,night-rain --distance 200".split_whitespace().map(String::from),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.scenarios, vec!["urban-rush".to_string(), "night-rain".to_string()]);
        assert_eq!(c.env.distances_m, vec![200.0]); // --distance aliases --dist
        c.scheduler = "minmin".into();
        let trials = c.plan().unwrap().trials().unwrap();
        assert_eq!(trials.len(), 2);
        assert!(trials.iter().all(|t| t.scenario.archetype.is_some()));

        let mut all = ExperimentConfig::default();
        all.apply_args(&Args::parse(["--scenario".to_string(), "all".to_string()])).unwrap();
        assert_eq!(all.scenarios, crate::env::scenario::names());

        let mut bad = ExperimentConfig::default();
        let err = bad
            .apply_args(&Args::parse(["--scenario".to_string(), "nope".to_string()]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown scenario"), "{err:#}");
    }

    #[test]
    fn scenarios_roundtrip_through_json() {
        let mut c = ExperimentConfig::default();
        c.scenarios = vec!["night-rain".into(), "cross-country".into()];
        c.events = true;
        c.flexai.seed = c.env.seed;
        let c2 = ExperimentConfig::from_json_text(&c.to_json().to_string()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn events_flag_enables_platform_events() {
        let mut c = ExperimentConfig::default();
        assert!(!c.events);
        let args = Args::parse(
            "--scenario accel-failure --distance 80 --events".split_whitespace().map(String::from),
        );
        c.apply_args(&args).unwrap();
        assert!(c.events);
        assert_eq!(c.scenarios, vec!["accel-failure".to_string()]);
    }

    #[test]
    fn replicates_expand_the_seed_axis() {
        let mut c = ExperimentConfig::default();
        c.scheduler = "minmin".into();
        c.env.distances_m = vec![100.0];
        c.apply_args(&Args::parse(["--replicates".to_string(), "3".to_string()])).unwrap();
        assert_eq!(c.replicates, 3);
        let trials = c.plan().unwrap().trials().unwrap();
        assert_eq!(trials.len(), 3);
        assert_eq!(trials[0].seed, c.env.seed, "replicate 0 is the base seed");
        let seeds: std::collections::BTreeSet<u64> = trials.iter().map(|t| t.seed).collect();
        assert_eq!(seeds.len(), 3);

        let mut bad = ExperimentConfig::default();
        let err = bad
            .apply_args(&Args::parse(["--replicates".to_string(), "0".to_string()]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("replicates"), "{err:#}");
    }

    #[test]
    fn topology_flag_suffixes_platform() {
        let mut c = ExperimentConfig::default();
        c.apply_args(&Args::parse(["--topology".to_string(), "mesh2x2".to_string()])).unwrap();
        assert_eq!(c.platform_spec(), "hmai+mesh2x2");
        let p = c.platform().unwrap();
        assert!(p.topology.is_some());
        assert_eq!(p.name, "HMAI(4SO,4SI,3MM)+mesh2x2");
        // `--topology mono` is explicit monolithic: parses and normalizes.
        c.topology = "mono".into();
        assert!(c.platform().unwrap().topology.is_none());
        // Bad suffixes keep the pointed topology error.
        c.topology = "torus9".into();
        let err = c.platform().unwrap_err().to_string();
        assert!(err.contains("torus9"), "{err}");
        // Round-trips through JSON like every other key.
        c.topology = "ring3@2x".into();
        c.flexai.seed = c.env.seed;
        let c2 = ExperimentConfig::from_json_text(&c.to_json().to_string()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn bad_area_is_error() {
        let mut c = ExperimentConfig::default();
        let args = Args::parse(["--area".to_string(), "mars".to_string()]);
        assert!(c.apply_args(&args).is_err());
    }
}
