//! Detector accuracy by object size (paper Table 3) and the heterogeneous-
//! CNN selection rule of §2.1: YOLO for small/medium objects, SSD for large.

use super::ModelKind;

/// COCO-style object size classes (paper §2.1): small < 32^2 px,
/// medium in [32^2, 96^2), large >= 96^2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectSize {
    Small,
    Medium,
    Large,
}

impl ObjectSize {
    pub fn from_area_px(area: f64) -> ObjectSize {
        if area < 32.0 * 32.0 {
            ObjectSize::Small
        } else if area < 96.0 * 96.0 {
            ObjectSize::Medium
        } else {
            ObjectSize::Large
        }
    }
}

/// One detector's AP by size class (paper Table 3 rows).
#[derive(Debug, Clone, Copy)]
pub struct ApRow {
    pub method: &'static str,
    pub backbone: &'static str,
    pub ap_s: f64,
    pub ap_m: f64,
    pub ap_l: f64,
}

/// Paper Table 3 verbatim.
pub const TABLE3: [ApRow; 4] = [
    ApRow { method: "YOLOv2", backbone: "DarkNet-53", ap_s: 18.3, ap_m: 35.4, ap_l: 41.9 },
    ApRow { method: "SSD312", backbone: "ResNet-101", ap_s: 6.2, ap_m: 28.3, ap_l: 49.3 },
    ApRow { method: "SSD512*", backbone: "VGG-16", ap_s: 10.9, ap_m: 31.8, ap_l: 43.5 },
    ApRow { method: "SSD513", backbone: "ResNet-101", ap_s: 10.2, ap_m: 34.5, ap_l: 49.8 },
];

/// §2.1 selection rule: YOLO leads on small & medium AP, SSD on large AP,
/// so detection tasks alternate per image but the *accuracy-optimal*
/// assignment keys on expected object size.
pub fn best_detector(size: ObjectSize) -> ModelKind {
    match size {
        ObjectSize::Small | ObjectSize::Medium => ModelKind::Yolo,
        ObjectSize::Large => ModelKind::Ssd,
    }
}

/// AP of a detector for a size class (best Table 3 row for that family).
pub fn ap(kind: ModelKind, size: ObjectSize) -> f64 {
    let best = |f: fn(&ApRow) -> f64, method_prefix: &str| {
        TABLE3
            .iter()
            .filter(|r| r.method.starts_with(method_prefix))
            .map(f)
            .fold(f64::MIN, f64::max)
    };
    match (kind, size) {
        (ModelKind::Yolo, ObjectSize::Small) => best(|r| r.ap_s, "YOLO"),
        (ModelKind::Yolo, ObjectSize::Medium) => best(|r| r.ap_m, "YOLO"),
        (ModelKind::Yolo, ObjectSize::Large) => best(|r| r.ap_l, "YOLO"),
        (ModelKind::Ssd, ObjectSize::Small) => best(|r| r.ap_s, "SSD"),
        (ModelKind::Ssd, ObjectSize::Medium) => best(|r| r.ap_m, "SSD"),
        (ModelKind::Ssd, ObjectSize::Large) => best(|r| r.ap_l, "SSD"),
        (ModelKind::Goturn, _) => f64::NAN, // tracker, not a detector
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes() {
        assert_eq!(ObjectSize::from_area_px(100.0), ObjectSize::Small);
        assert_eq!(ObjectSize::from_area_px(4620.0), ObjectSize::Medium);
        assert_eq!(ObjectSize::from_area_px(42000.0), ObjectSize::Large);
    }

    #[test]
    fn selection_rule_matches_table3() {
        // YOLO wins small+medium, SSD wins large — the paper's motivation
        // for heterogeneous CNNs.
        assert!(ap(ModelKind::Yolo, ObjectSize::Small) > ap(ModelKind::Ssd, ObjectSize::Small));
        assert!(ap(ModelKind::Yolo, ObjectSize::Medium) > ap(ModelKind::Ssd, ObjectSize::Medium));
        assert!(ap(ModelKind::Ssd, ObjectSize::Large) > ap(ModelKind::Yolo, ObjectSize::Large));
        assert_eq!(best_detector(ObjectSize::Small), ModelKind::Yolo);
        assert_eq!(best_detector(ObjectSize::Large), ModelKind::Ssd);
    }
}
