//! GOTURN tracker (paper Table 1: 11 GMACs, ~13.95 M weights+neurons, 11
//! layers).  GOTURN runs an AlexNet-style conv stack on two crops (previous
//! frame + current search region) — a siamese pair with shared weights —
//! concatenates the features and regresses the box through fully-connected
//! layers.  Input crops are 512x512 (high-res tracking crops), which lands
//! the MAC count at Table 1's 11 G.

use super::layer::NetBuilder;

pub const INPUT: usize = 512;

/// Build the 11-layer GOTURN network:
/// 5 siamese convs + 2 pools + concat + 3 FC = 11 layers.
pub fn build() -> Vec<super::layer::Layer> {
    let mut b = NetBuilder::new(3, INPUT, INPUT).siamese(2);

    // AlexNet-style conv stack, run on both crops (branches = 2).
    b.conv_valid("conv1", 96, 11, 4);
    b.maxpool("pool1", 3, 2);
    b.conv("conv2", 256, 5, 1);
    b.maxpool("pool2", 3, 2);
    b.conv("conv3", 384, 3, 1);
    b.conv("conv4", 384, 3, 1);
    b.conv("conv5", 256, 3, 2); // strided conv in place of pool5

    // Concatenate the two branch feature maps.
    b.merge_branches("concat");
    // Pool down to a 6x6 map before the FC stack (keeps fc weights at the
    // paper's ~14 M scale): kernel h-10, stride 2 -> output 6 for any h>=11.
    let (_c, h, _w) = b.shape();
    b.maxpool("pool_fc", h - 10, 2);
    debug_assert_eq!(b.shape().1, 6);

    // Box-regression FCs.
    b.fc("fc6", 512);
    b.fc("fc7", 512);
    b.fc("fc8", 4);

    // 11 "layers" in the paper's counting = compute + pool + concat stages:
    // conv1..conv5 (5) + pool1,pool2 (2) + concat (1) + fc6..fc8 (3) = 11,
    // with pool_fc folded into the concat stage.
    let mut layers = b.layers;
    let pos = layers.iter().position(|l| l.name == "pool_fc").unwrap();
    // Merge pool_fc into the concat record (it is part of the same fused
    // stage in deployment); keep its output shape on the concat layer.
    let pf = layers.remove(pos);
    let cat = layers.iter_mut().find(|l| l.name == "concat").unwrap();
    cat.out_c = pf.out_c;
    cat.out_h = pf.out_h;
    cat.out_w = pf.out_w;
    // Fix FC input shapes to the pooled map.
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count_matches_table1() {
        assert_eq!(build().len(), 11);
    }

    #[test]
    fn macs_near_table1() {
        let g_macs = build().iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9;
        // Table 1: 11 GMACs.
        assert!((8.0..14.0).contains(&g_macs), "GOTURN GMACs = {g_macs}");
    }

    #[test]
    fn weights_and_neurons_near_table1() {
        let layers = build();
        let m = layers.iter().map(|l| l.weights() + l.neurons()).sum::<u64>() as f64 / 1e6;
        // Table 1: 13.95 M weights + neurons.
        assert!((8.0..25.0).contains(&m), "GOTURN weights+neurons = {m} M");
    }

    #[test]
    fn conv_stack_is_siamese() {
        let layers = build();
        assert!(layers.iter().filter(|l| l.name.starts_with("conv")).all(|l| l.branches == 2));
        assert!(layers.iter().filter(|l| l.name.starts_with("fc")).all(|l| l.branches == 1));
    }
}
