//! YOLO detector (paper Table 1: 16 GMACs, ~150 M weights+neurons, 101
//! layers).  The paper's "YOLO" mixes YOLOv2 citations with a DarkNet-53
//! backbone (Table 3); the layer count (101) matches a YOLOv3-style network
//! = DarkNet-53 backbone + 3-scale detection head.  We build that topology
//! at 288x288 input, which lands the MAC count at Table 1's 16 G.

use super::layer::NetBuilder;

pub const INPUT: usize = 288;

/// DarkNet-53 residual stage: downsample conv + n x (1x1 half, 3x3 full,
/// shortcut).
fn stage(b: &mut NetBuilder, out_c: usize, n: usize, idx: &mut usize) {
    b.conv(&format!("conv{}_down", idx), out_c, 3, 2);
    *idx += 1;
    for i in 0..n {
        b.conv(&format!("conv{}_res{}a", idx, i), out_c / 2, 1, 1);
        b.conv(&format!("conv{}_res{}b", idx, i), out_c, 3, 1);
        b.shortcut(&format!("shortcut{}_{}", idx, i));
        *idx += 1;
    }
}

/// Detection head block at one scale: alternating 1x1 / 3x3 convs + the
/// prediction conv + detect decode.
fn head(b: &mut NetBuilder, mid_c: usize, n_pairs: usize, tag: &str) {
    for i in 0..n_pairs {
        b.conv(&format!("head_{tag}_{i}a"), mid_c, 1, 1);
        b.conv(&format!("head_{tag}_{i}b"), mid_c * 2, 3, 1);
    }
    b.conv(&format!("head_{tag}_pred"), 255, 1, 1);
    b.detect(&format!("detect_{tag}"));
}

/// Build the 101-layer YOLO network.
pub fn build() -> Vec<super::layer::Layer> {
    let mut b = NetBuilder::new(3, INPUT, INPUT);
    let mut idx = 0usize;

    b.conv("conv0", 32, 3, 1); // stem
    stage(&mut b, 64, 1, &mut idx); //  4 layers
    stage(&mut b, 128, 2, &mut idx); //  7
    stage(&mut b, 256, 8, &mut idx); // 25  (route source @ 36x36)
    let (c36, h36, w36) = b.shape();
    stage(&mut b, 512, 8, &mut idx); // 25  (route source @ 18x18)
    let (c18, h18, w18) = b.shape();
    stage(&mut b, 1024, 4, &mut idx); // 13  -> backbone = 1+4+7+25+25+13 = 75

    // Scale 1 head (9x9): 2 conv pairs + pred + detect = 6 layers.
    head(&mut b, 512, 2, "s1"); // 75 + 6 = 81
    // Upsample path to scale 2: 1x1 conv + upsample + route(concat) = 3.
    b.conv("up1_conv", 256, 1, 1);
    b.upsample("up1");
    b.route("route1", c18 + 256, h18, w18); // 84
    head(&mut b, 256, 2, "s2"); // 90
    b.conv("up2_conv", 128, 1, 1);
    b.upsample("up2");
    b.route("route2", c36 + 128, h36, w36); // 93
    head(&mut b, 128, 2, "s3"); // 99
    // Two final refinement convs on the fused fine scale (brings the layer
    // count to the paper's 101 and the MACs to ~16 G).
    b.conv("refine1", 256, 3, 1);
    b.conv("refine2", 128, 1, 1); // 101

    b.layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count_matches_table1() {
        assert_eq!(build().len(), 101);
    }

    #[test]
    fn macs_near_table1() {
        let g_macs = build().iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9;
        // Table 1: 16 GMACs.
        assert!((12.0..20.0).contains(&g_macs), "YOLO GMACs = {g_macs}");
    }

    #[test]
    fn weights_and_neurons_near_table1() {
        let layers = build();
        let m = layers.iter().map(|l| l.weights() + l.neurons()).sum::<u64>() as f64 / 1e6;
        // Table 1: 150 M weights + neurons.
        assert!((60.0..250.0).contains(&m), "YOLO weights+neurons = {m} M");
    }
}
