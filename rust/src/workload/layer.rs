//! Per-layer CNN shape records.  The accelerator cycle models (accel/)
//! consume these to derive cycles and memory traffic per layer; the
//! workload zoo (yolo.rs / ssd.rs / goturn.rs) builds them.

/// Layer operator kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution with square kernel `k`, stride and padding.
    Conv { k: usize, stride: usize, pad: usize },
    /// Max pooling.
    MaxPool { k: usize, stride: usize },
    /// Fully connected (in = in_c*in_h*in_w flattened, out = out_c).
    Fc,
    /// Residual add (YOLOv3 shortcut).
    Shortcut,
    /// Concatenating route (YOLOv3) / siamese feature concat (GOTURN).
    Route,
    /// Nearest-neighbour 2x upsample.
    Upsample,
    /// Detection decode (YOLO head / SSD priorbox+decode).
    Detect,
}

/// One layer with resolved input/output shapes.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub out_h: usize,
    pub out_w: usize,
    /// Siamese branches (GOTURN runs each conv on both crops): multiplies
    /// MACs and activations, weights are shared.
    pub branches: usize,
}

impl Layer {
    /// Multiply-accumulate count for one inference.
    pub fn macs(&self) -> u64 {
        let b = self.branches as u64;
        match self.kind {
            LayerKind::Conv { k, .. } => {
                b * (self.out_c * self.out_h * self.out_w) as u64
                    * (self.in_c * k * k) as u64
            }
            LayerKind::Fc => b * (self.in_c * self.in_h * self.in_w) as u64 * self.out_c as u64,
            // Pool/route/shortcut/upsample/detect do data movement, not MACs.
            _ => 0,
        }
    }

    /// Weight (parameter) count; shared across siamese branches.
    pub fn weights(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { k, .. } => (self.out_c * self.in_c * k * k + self.out_c) as u64,
            LayerKind::Fc => (self.in_c * self.in_h * self.in_w * self.out_c + self.out_c) as u64,
            _ => 0,
        }
    }

    /// Output activation (neuron) count.
    pub fn neurons(&self) -> u64 {
        self.branches as u64 * (self.out_c * self.out_h * self.out_w) as u64
    }

    /// Input activation element count (per branch x branches).
    pub fn input_elems(&self) -> u64 {
        self.branches as u64 * (self.in_c * self.in_h * self.in_w) as u64
    }

    pub fn is_compute(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. } | LayerKind::Fc)
    }
}

/// Incremental network builder tracking the current feature-map shape.
#[derive(Debug)]
pub struct NetBuilder {
    pub layers: Vec<Layer>,
    c: usize,
    h: usize,
    w: usize,
    branches: usize,
}

impl NetBuilder {
    pub fn new(in_c: usize, in_h: usize, in_w: usize) -> Self {
        Self { layers: Vec::new(), c: in_c, h: in_h, w: in_w, branches: 1 }
    }

    pub fn siamese(mut self, branches: usize) -> Self {
        self.branches = branches;
        self
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }

    fn push(&mut self, name: &str, kind: LayerKind, out_c: usize, out_h: usize, out_w: usize) {
        self.layers.push(Layer {
            name: name.to_string(),
            kind,
            in_c: self.c,
            in_h: self.h,
            in_w: self.w,
            out_c,
            out_h,
            out_w,
            branches: self.branches,
        });
        self.c = out_c;
        self.h = out_h;
        self.w = out_w;
    }

    pub fn conv(&mut self, name: &str, out_c: usize, k: usize, stride: usize) -> &mut Self {
        let pad = k / 2;
        let oh = (self.h + 2 * pad - k) / stride + 1;
        let ow = (self.w + 2 * pad - k) / stride + 1;
        self.push(name, LayerKind::Conv { k, stride, pad }, out_c, oh, ow);
        self
    }

    /// Valid (unpadded) convolution, AlexNet-style.
    pub fn conv_valid(&mut self, name: &str, out_c: usize, k: usize, stride: usize) -> &mut Self {
        let oh = (self.h - k) / stride + 1;
        let ow = (self.w - k) / stride + 1;
        self.push(name, LayerKind::Conv { k, stride, pad: 0 }, out_c, oh, ow);
        self
    }

    pub fn maxpool(&mut self, name: &str, k: usize, stride: usize) -> &mut Self {
        let oh = (self.h - k) / stride + 1;
        let ow = (self.w - k) / stride + 1;
        self.push(name, LayerKind::MaxPool { k, stride }, self.c, oh, ow);
        self
    }

    pub fn fc(&mut self, name: &str, out: usize) -> &mut Self {
        self.push(name, LayerKind::Fc, out, 1, 1);
        self
    }

    pub fn shortcut(&mut self, name: &str) -> &mut Self {
        self.push(name, LayerKind::Shortcut, self.c, self.h, self.w);
        self
    }

    /// Route that (re)sets the current shape, optionally concatenating
    /// `extra_c` channels from the source being routed in.
    pub fn route(&mut self, name: &str, c: usize, h: usize, w: usize) -> &mut Self {
        self.push(name, LayerKind::Route, c, h, w);
        self
    }

    pub fn upsample(&mut self, name: &str) -> &mut Self {
        self.push(name, LayerKind::Upsample, self.c, self.h * 2, self.w * 2);
        self
    }

    pub fn detect(&mut self, name: &str) -> &mut Self {
        self.push(name, LayerKind::Detect, self.c, self.h, self.w);
        self
    }

    /// End the siamese section: subsequent layers run once on concatenated
    /// features (`route` with doubled channels).
    pub fn merge_branches(&mut self, name: &str) -> &mut Self {
        let (c, h, w) = (self.c * self.branches, self.h, self.w);
        self.branches = 1;
        self.route(name, c, h, w);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_math() {
        let mut b = NetBuilder::new(3, 416, 416);
        b.conv("c1", 32, 3, 1);
        assert_eq!(b.shape(), (32, 416, 416));
        b.conv("c2", 64, 3, 2);
        assert_eq!(b.shape(), (64, 208, 208));
    }

    #[test]
    fn conv_macs_weights() {
        let mut b = NetBuilder::new(3, 8, 8);
        b.conv("c", 16, 3, 1);
        let l = &b.layers[0];
        assert_eq!(l.macs(), (16 * 8 * 8) as u64 * (3 * 3 * 3) as u64);
        assert_eq!(l.weights(), (16 * 3 * 3 * 3 + 16) as u64);
        assert_eq!(l.neurons(), 16 * 8 * 8);
    }

    #[test]
    fn fc_macs() {
        let mut b = NetBuilder::new(256, 6, 6);
        b.fc("fc", 512);
        let l = &b.layers[0];
        assert_eq!(l.macs(), (256 * 6 * 6 * 512) as u64);
        assert_eq!(l.weights(), (256 * 6 * 6 * 512 + 512) as u64);
    }

    #[test]
    fn siamese_doubles_macs_not_weights() {
        let mut a = NetBuilder::new(3, 64, 64);
        a.conv("c", 8, 3, 1);
        let mut s = NetBuilder::new(3, 64, 64).siamese(2);
        s.conv("c", 8, 3, 1);
        assert_eq!(s.layers[0].macs(), 2 * a.layers[0].macs());
        assert_eq!(s.layers[0].weights(), a.layers[0].weights());
    }

    #[test]
    fn pool_no_macs() {
        let mut b = NetBuilder::new(16, 8, 8);
        b.maxpool("p", 2, 2);
        assert_eq!(b.layers[0].macs(), 0);
        assert_eq!(b.shape(), (16, 4, 4));
    }

    #[test]
    fn merge_branches_concats_channels() {
        let mut b = NetBuilder::new(3, 32, 32).siamese(2);
        b.conv("c", 8, 3, 1);
        b.merge_branches("cat");
        assert_eq!(b.shape(), (16, 32, 32));
    }
}
