//! SSD detector (paper Table 1: 26 GMACs, ~697.76 M weights+neurons, 53
//! layers).  VGG-16 backbone with fc6/fc7 as dilated convs, extra feature
//! stages, and 6 (loc, conf) prediction heads — the classic SSD topology —
//! at 288x288 input, landing MACs near Table 1's 26 G.

use super::layer::NetBuilder;

pub const INPUT: usize = 288;

/// Build the 53-layer SSD network.
pub fn build() -> Vec<super::layer::Layer> {
    let mut b = NetBuilder::new(3, INPUT, INPUT);

    // VGG-16 backbone: 13 convs + 5 pools = 18 layers.
    b.conv("conv1_1", 64, 3, 1).conv("conv1_2", 64, 3, 1).maxpool("pool1", 2, 2);
    b.conv("conv2_1", 128, 3, 1).conv("conv2_2", 128, 3, 1).maxpool("pool2", 2, 2);
    b.conv("conv3_1", 256, 3, 1)
        .conv("conv3_2", 256, 3, 1)
        .conv("conv3_3", 256, 3, 1)
        .maxpool("pool3", 2, 2);
    b.conv("conv4_1", 512, 3, 1)
        .conv("conv4_2", 512, 3, 1)
        .conv("conv4_3", 512, 3, 1); // head source 1 @ 48x48
    let s1 = b.shape();
    b.maxpool("pool4", 2, 2);
    b.conv("conv5_1", 512, 3, 1)
        .conv("conv5_2", 512, 3, 1)
        .conv("conv5_3", 512, 3, 1)
        .maxpool("pool5", 2, 2);

    // fc6 / fc7 as convs (SSD): 2 layers.  head source 2 @ 12x12.
    b.conv("fc6_conv", 1024, 3, 1);
    b.conv("fc7_conv", 1024, 1, 1);
    let s2 = b.shape();

    // Extra feature stages conv6..conv9: 8 layers, head sources 3..6.
    b.conv("conv6_1", 256, 1, 1).conv("conv6_2", 512, 3, 2);
    let s3 = b.shape();
    b.conv("conv7_1", 128, 1, 1).conv("conv7_2", 256, 3, 2);
    let s4 = b.shape();
    b.conv("conv8_1", 128, 1, 1).conv("conv8_2", 256, 3, 2);
    let s5 = b.shape();
    b.conv("conv9_1", 128, 1, 1).conv("conv9_2", 256, 3, 2);
    let s6 = b.shape();

    // Prediction heads: 6 scales x (route to source + 1x1 feature-smooth
    // conv + loc conv + conf conv) = 24 layers, then one fused detect
    // decode.  Anchors per cell: 4,6,6,6,4,4 (SSD defaults).
    let sources = [(s1, 4), (s2, 6), (s3, 6), (s4, 6), (s5, 4), (s6, 4)];
    for (i, ((c, h, w), anchors)) in sources.iter().enumerate() {
        b.route(&format!("head_src{}", i + 1), *c, *h, *w);
        b.conv(&format!("smooth{}", i + 1), (*c / 2).max(128), 1, 1);
        let (sc, sh, sw) = b.shape();
        b.conv(&format!("loc{}", i + 1), anchors * 4, 3, 1);
        b.route(&format!("head_back{}", i + 1), sc, sh, sw);
        b.conv(&format!("conf{}", i + 1), anchors * 21, 3, 1);
        // Fold the loc/conf fan-out route back out of the layer list: it is
        // bookkeeping, not a deployed data movement.
        let back = b
            .layers
            .iter()
            .position(|l| l.name == format!("head_back{}", i + 1))
            .unwrap();
        b.layers.remove(back);
    }
    b.detect("detect");

    // 18 (VGG) + 2 (fc6/7) + 8 (extras) + 24 (heads) + 1 (detect) = 53.
    b.layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count_matches_table1() {
        assert_eq!(build().len(), 53);
    }

    #[test]
    fn macs_near_table1() {
        let g_macs = build().iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9;
        // Table 1: 26 GMACs.
        assert!((20.0..32.0).contains(&g_macs), "SSD GMACs = {g_macs}");
    }

    #[test]
    fn weights_and_neurons_near_table1() {
        let layers = build();
        let m = layers.iter().map(|l| l.weights() + l.neurons()).sum::<u64>() as f64 / 1e6;
        // Table 1: 697.76 M weights + neurons.  VGG-era SSD parameter counts
        // vary with the number of classes; accept a broad band.
        assert!((40.0..800.0).contains(&m), "SSD weights+neurons = {m} M");
    }

    #[test]
    fn has_six_loc_conf_head_pairs() {
        let layers = build();
        let locs = layers.iter().filter(|l| l.name.starts_with("loc")).count();
        let confs = layers.iter().filter(|l| l.name.starts_with("conf")).count();
        assert_eq!((locs, confs), (6, 6));
    }
}
