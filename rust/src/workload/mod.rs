//! CNN workload zoo: the three perception networks the paper schedules
//! (YOLO + SSD for detection, GOTURN for tracking; §2.1, Table 1), with
//! per-layer shape records consumed by the accelerator cycle models.

pub mod accuracy;
pub mod goturn;
pub mod layer;
pub mod ssd;
pub mod yolo;

use std::sync::OnceLock;

pub use layer::{Layer, LayerKind};

/// The three CNN task types in the driving-automation workload mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Yolo,
    Ssd,
    Goturn,
}

pub const ALL_MODELS: [ModelKind; 3] = [ModelKind::Yolo, ModelKind::Ssd, ModelKind::Goturn];

impl ModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Yolo => "YOLO",
            ModelKind::Ssd => "SSD",
            ModelKind::Goturn => "GOTURN",
        }
    }

    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "yolo" => Some(ModelKind::Yolo),
            "ssd" => Some(ModelKind::Ssd),
            "goturn" => Some(ModelKind::Goturn),
            _ => None,
        }
    }

    /// Task category: detection (DET) or tracking (TRA), §2.1.
    pub fn is_tracker(&self) -> bool {
        matches!(self, ModelKind::Goturn)
    }

    /// Index used in one-hot featurization (must match python model.py).
    pub fn index(&self) -> usize {
        match self {
            ModelKind::Yolo => 0,
            ModelKind::Ssd => 1,
            ModelKind::Goturn => 2,
        }
    }
}

/// A network: name + resolved layer list + cached aggregates.
#[derive(Debug, Clone)]
pub struct Model {
    pub kind: ModelKind,
    pub layers: Vec<Layer>,
    pub total_macs: u64,
    pub total_weights: u64,
    pub total_neurons: u64,
}

impl Model {
    fn build(kind: ModelKind) -> Model {
        let layers = match kind {
            ModelKind::Yolo => yolo::build(),
            ModelKind::Ssd => ssd::build(),
            ModelKind::Goturn => goturn::build(),
        };
        let total_macs = layers.iter().map(Layer::macs).sum();
        let total_weights = layers.iter().map(Layer::weights).sum();
        let total_neurons = layers.iter().map(Layer::neurons).sum();
        Model { kind, layers, total_macs, total_weights, total_neurons }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn gmacs(&self) -> f64 {
        self.total_macs as f64 / 1e9
    }

    /// Table 1's "#of weights and neurons" column, in millions.
    pub fn mweights_neurons(&self) -> f64 {
        (self.total_weights + self.total_neurons) as f64 / 1e6
    }
}

/// Cached model lookup (layer lists are immutable after construction).
pub fn model(kind: ModelKind) -> &'static Model {
    static YOLO: OnceLock<Model> = OnceLock::new();
    static SSD: OnceLock<Model> = OnceLock::new();
    static GOTURN: OnceLock<Model> = OnceLock::new();
    match kind {
        ModelKind::Yolo => YOLO.get_or_init(|| Model::build(ModelKind::Yolo)),
        ModelKind::Ssd => SSD.get_or_init(|| Model::build(ModelKind::Ssd)),
        ModelKind::Goturn => GOTURN.get_or_init(|| Model::build(ModelKind::Goturn)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_builds_and_caches() {
        for kind in ALL_MODELS {
            let m = model(kind);
            assert!(m.total_macs > 0);
            assert!(m.num_layers() > 0);
            // Cached: same allocation on second call.
            assert!(std::ptr::eq(m, model(kind)));
        }
    }

    #[test]
    fn table1_layer_counts() {
        assert_eq!(model(ModelKind::Ssd).num_layers(), 53);
        assert_eq!(model(ModelKind::Yolo).num_layers(), 101);
        assert_eq!(model(ModelKind::Goturn).num_layers(), 11);
    }

    #[test]
    fn table1_mac_ordering() {
        // SSD > YOLO > GOTURN in MACs (26G > 16G > 11G).
        assert!(model(ModelKind::Ssd).total_macs > model(ModelKind::Yolo).total_macs);
        assert!(model(ModelKind::Yolo).total_macs > model(ModelKind::Goturn).total_macs);
    }

    #[test]
    fn kind_roundtrip() {
        for kind in ALL_MODELS {
            assert_eq!(ModelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::parse("nope"), None);
    }
}
