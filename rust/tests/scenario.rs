//! Golden determinism contract of the scenario-variability library,
//! mirroring tests/sweep.rs: for every archetype, the same seed yields a
//! bit-identical `SweepSummary::fingerprint` across `--jobs 1` and
//! `--jobs N`, across reruns, and distinct archetypes never collide into
//! one sweep row.

use hmai::engine::Engine;
use hmai::env::scenario;
use hmai::plan::ExperimentPlan;
use hmai::sched::{Registry, SchedulerSpec};

fn plan_for(name: &str) -> ExperimentPlan {
    ExperimentPlan::new()
        .scenarios([name.to_string()])
        .distances([50.0, 75.0])
        .schedulers([SchedulerSpec::MinMin, SchedulerSpec::Random])
        .seed(42)
}

#[test]
fn every_archetype_fingerprint_is_jobs_invariant() {
    let reg = Registry::new();
    for name in scenario::names() {
        let plan = plan_for(&name);
        let (seq_results, seq) = Engine::new(&reg).jobs(1).sweep(&plan).unwrap();
        assert!(seq_results.iter().all(|r| r.summary.tasks > 0), "{name}: empty trial");
        for jobs in [2, 4] {
            let (_, par) = Engine::new(&reg).jobs(jobs).sweep(&plan).unwrap();
            assert_eq!(
                seq.fingerprint(),
                par.fingerprint(),
                "{name}: fingerprint drifted at jobs={jobs}"
            );
        }
        // Rerun-stable: no hidden state in archetype compilation.
        let (_, again) = Engine::new(&reg).jobs(1).sweep(&plan).unwrap();
        assert_eq!(seq.fingerprint(), again.fingerprint(), "{name}: rerun drifted");
    }
}

#[test]
fn archetypes_have_distinct_fingerprints() {
    // Different archetypes produce different workloads — their sweep
    // fingerprints must differ (a collision would mean the scenario axis
    // is not actually reaching queue generation).
    let reg = Registry::new();
    let mut prints = std::collections::BTreeMap::new();
    for name in scenario::names() {
        let plan = ExperimentPlan::new()
            .scenarios([name.clone()])
            .distances([60.0])
            .scheduler(SchedulerSpec::MinMin)
            .seed(7);
        let (_, sweep) = Engine::new(&reg).jobs(1).sweep(&plan).unwrap();
        if let Some(other) = prints.insert(sweep.fingerprint(), name.clone()) {
            panic!("{name} and {other} share a fingerprint");
        }
    }
}

#[test]
fn scenario_cross_product_keeps_one_row_per_archetype() {
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .all_scenarios()
        .distances([50.0])
        .scheduler(SchedulerSpec::RoundRobin)
        .seed(3);
    let (results, sweep) = Engine::new(&reg).jobs(3).sweep(&plan).unwrap();
    let names = scenario::names();
    assert_eq!(results.len(), names.len());
    assert_eq!(sweep.groups.len(), names.len());
    let rows: Vec<String> = sweep.groups.iter().map(|g| g.key.scenario.clone()).collect();
    assert_eq!(rows, names, "sweep rows follow library order");
    // The rendered sweep table carries the per-scenario breakdown.
    let rendered = hmai::reports::sweep_table(&sweep).render();
    for name in &names {
        assert!(rendered.contains(name.as_str()), "{name} missing:\n{rendered}");
    }
}
