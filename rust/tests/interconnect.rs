//! Interconnect integration suite: the chiplet communication model must be
//! (a) invisible on monolithic platforms — every mono spelling of a
//! platform spec is bit-identical to the compute-only model, (b) identical
//! between the optimized schedulers and `sched::reference` on chiplet
//! platforms, (c) invariant under the `jobs` split with platform events
//! on, (d) actually visible (non-zero comm time/bytes) on chiplet
//! platforms, and (e) strong enough to put a chiplet candidate on the DSE
//! Pareto frontier when the workload outgrows one reticle.

use hmai::dse::{self, DseConfig, FidelityMode, SearchMode};
use hmai::engine::Engine;
use hmai::env::taskgen::DeadlineMode;
use hmai::metrics::summary::SweepSummary;
use hmai::plan::ExperimentPlan;
use hmai::sched::reference::reference_registry;
use hmai::sched::{Registry, SchedulerSpec};

/// Every registered scheduler except FlexAI (needs a PJRT runtime — the
/// one spec the base registry cannot build, same gap in both registries).
fn all_specs() -> Vec<SchedulerSpec> {
    [
        SchedulerSpec::MinMin,
        SchedulerSpec::Ata,
        SchedulerSpec::Edp,
        SchedulerSpec::Ga,
        SchedulerSpec::Sa,
        SchedulerSpec::Worst,
        SchedulerSpec::RoundRobin,
        SchedulerSpec::Random,
    ]
    .to_vec()
}

fn sweep(reg: &Registry, plan: &ExperimentPlan, events: bool, jobs: usize) -> SweepSummary {
    Engine::new(reg).jobs(jobs).events(events).sweep_streaming(plan).unwrap()
}

#[test]
fn mono_topology_spellings_are_bit_identical_to_compute_only() {
    // `+mono`, `+mesh1x1` and `+ring1` all normalize to a topology-free
    // platform with an unchanged name: the sweep fingerprint (which folds
    // every per-run metric, comm fields included) must not move a bit
    // for any registered scheduler.
    let reg = Registry::new();
    let plan_for = |spec: &str| {
        ExperimentPlan::new()
            .platforms([spec])
            .scenarios(["urban-rush"])
            .distances([40.0])
            .schedulers(all_specs())
            .seed(7)
    };
    let base = sweep(&reg, &plan_for("hmai"), false, 2).fingerprint();
    for spelling in ["hmai+mono", "hmai+mesh1x1", "hmai+ring1"] {
        let fp = sweep(&reg, &plan_for(spelling), false, 2).fingerprint();
        assert_eq!(fp, base, "{spelling} drifted from the compute-only model");
    }
}

#[test]
fn optimized_matches_reference_on_chiplet_platforms() {
    // The sharpest cross-check of the comm fast paths: the incremental
    // Min-Min cache, the RolloutCtx comm mirror and the route-mask
    // invalidation must reproduce the reference ShadowState decisions
    // exactly — on a preset topology and on a mixed-core ring with an
    // explicit non-trivial placement of its 11 slots over 3 chiplets.
    let plan = ExperimentPlan::new()
        .platforms(["hmai+mesh2x2", "so:4@2x,si:4,mm:3@0.5x+ring3/0.1.2.0.1.2.0.1.2.0.1"])
        .scenarios(["urban-rush"])
        .distances([40.0])
        .schedulers(all_specs())
        .seed(3);
    let fast = sweep(&Registry::new(), &plan, false, 2).fingerprint();
    let slow = sweep(&reference_registry(), &plan, false, 2).fingerprint();
    assert_eq!(fast, slow, "chiplet sweep drifted from the reference schedulers");
}

#[test]
fn jobs_split_is_invariant_on_chiplet_platform_with_events() {
    // Comm state is part of the per-run simulation state; sharding the
    // sweep across workers must not leak it between runs — including
    // through a mid-route accelerator failure and recovery.
    let plan = ExperimentPlan::new()
        .platforms(["hmai+mesh2x2"])
        .scenarios(["accel-failure"])
        .distances([60.0])
        .schedulers(all_specs())
        .seed(11);
    let serial = sweep(&Registry::new(), &plan, true, 1).fingerprint();
    let sharded = sweep(&Registry::new(), &plan, true, 3).fingerprint();
    assert_eq!(serial, sharded, "jobs split changed a chiplet sweep");
}

#[test]
fn chiplet_comm_is_visible_and_mono_comm_is_zero() {
    let plan = ExperimentPlan::new()
        .platforms(["hmai", "hmai+mesh2x2"])
        .scenarios(["urban-rush"])
        .distances([40.0])
        .schedulers([SchedulerSpec::MinMin])
        .seed(7);
    let results = Engine::new(&Registry::new()).run(&plan).unwrap();
    let mut saw = (false, false);
    for r in &results {
        if r.trial.platform.contains("+mesh2x2") {
            saw.0 = true;
            assert!(r.summary.comm_delay_s > 0.0, "mesh run moved no comm time");
            assert!(r.summary.comm_gb > 0.0, "mesh run moved no bytes");
            assert!(
                r.summary.makespan_s > 0.0 && r.summary.comm_delay_s < r.summary.compute_s,
                "comm should tax the run, not dominate it"
            );
        } else {
            saw.1 = true;
            assert_eq!(r.summary.comm_delay_s.to_bits(), 0.0_f64.to_bits());
            assert_eq!(r.summary.comm_gb.to_bits(), 0.0_f64.to_bits());
        }
    }
    assert!(saw.0 && saw.1, "plan must cover both platforms");
}

#[test]
fn dse_topology_sweep_puts_a_chiplet_on_the_frontier() {
    // ISSUE 8 acceptance: a 20-camera scenario whose affine demand
    // (~14 std-core-equivalents) exceeds one reticle (12 area units).
    // With the topology axis on, monolithic candidates are capped at one
    // die while mesh2x2 candidates may spend the full 16-unit budget
    // across 4 dies — under frame-budget deadlines the extra capacity
    // beats the comm tax, so at least one chiplet candidate must be
    // Pareto-optimal.  Exact fidelity: the per-axis structural floors
    // below ("mono >= 12") count *every* searched candidate, which
    // multi-fidelity screening legitimately thins out.  The 90 m route
    // keeps the 20-camera load saturating long enough that the best mesh
    // candidate's capacity edge over one reticle is decisive, not a
    // coin-flip on queue tail effects.
    let cfg = DseConfig {
        budget_area: 16.0,
        scenarios: vec!["urban-rush-20cam-hd".to_string()],
        distances_m: vec![90.0],
        deadline: DeadlineMode::FrameBudget,
        max_evals: 24,
        search: SearchMode::Full,
        topologies: vec!["mesh2x2".to_string()],
        jobs: 2,
        fidelity: FidelityMode::Exact,
        ..DseConfig::default()
    };
    let report = dse::run(&cfg, &Registry::new()).unwrap();
    assert_eq!(report.topologies, vec!["mono".to_string(), "mesh2x2".to_string()]);

    let (mut mono, mut mesh) = (0usize, 0usize);
    for r in &report.rows {
        if r.topology == "mono" {
            mono += 1;
            assert_eq!(r.chiplets, 1, "{}", r.spec);
            assert!(r.area <= 12.0 + 1e-9, "reticle cap violated: {} ({})", r.spec, r.area);
            assert_eq!(r.comm_delay_ms_per_task.to_bits(), 0.0_f64.to_bits(), "{}", r.spec);
        } else {
            mesh += 1;
            assert_eq!(r.topology, "mesh2x2");
            assert_eq!(r.chiplets, 4, "{}", r.spec);
            assert!(r.spec.ends_with("+mesh2x2"), "{}", r.spec);
            assert!(r.area <= 16.0 + 1e-9, "{} ({})", r.spec, r.area);
        }
    }
    assert!(mono >= 12 && mesh >= 12, "both axes must get their eval share ({mono}/{mesh})");
    // The capacity shortlist must actually use the beyond-reticle headroom
    // only chiplets can reach, and pay visible communication for it.
    assert!(
        report.rows.iter().any(|r| r.topology == "mesh2x2" && r.area > 12.0 + 1e-9),
        "no mesh candidate beyond one reticle"
    );
    assert!(
        report.rows.iter().any(|r| r.topology == "mesh2x2" && r.comm_delay_ms_per_task > 0.0),
        "mesh candidates paid no comm"
    );
    // The acceptance bar itself, asserted on the mesh axis' best-STM row
    // directly: the best mesh candidate must strictly beat every reticle-
    // capped monolithic candidate on deadline-met rate (the capacity the
    // workload cannot reach on one die), which makes it mono-undominated
    // and therefore a frontier member — no reliance on how the rest of
    // the frontier shakes out.
    let best = |topo: &str| {
        report
            .rows
            .iter()
            .filter(|r| r.topology == topo)
            .map(|r| r.stm_rate)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    let (best_mesh, best_mono) = (best("mesh2x2"), best("mono"));
    assert!(
        best_mesh > best_mono,
        "best mesh STM {best_mesh} does not beat best mono STM {best_mono}: {:?}",
        report
            .rows
            .iter()
            .map(|r| (r.spec.clone(), r.on_frontier, r.stm_rate, r.energy_j, r.area))
            .collect::<Vec<_>>()
    );
    assert!(
        report.frontier_rows().any(|r| r.topology == "mesh2x2"),
        "no mesh candidate on the Pareto frontier despite winning on STM"
    );
}
