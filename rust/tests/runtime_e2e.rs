//! End-to-end AOT-path tests: the compiled HLO executables (L1 Pallas
//! kernels inlined into the L2 JAX graph) are cross-checked against an
//! independent pure-rust re-implementation of the Q-network math, and the
//! FlexAI train/checkpoint/serve cycle is exercised through PJRT.
//!
//! These tests require `make artifacts` (and the `pjrt` build feature);
//! without either they skip with a message instead of failing.

// Self-skipping tests explain themselves on stderr (deny carve-out).
#![allow(clippy::print_stderr)]

use std::sync::Arc;

use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::plan::queue_for;
use hmai::platform::Platform;
use hmai::runtime::{Params, Runtime, TrainBatch};
use hmai::sched::flexai::{checkpoint, FlexAI, FlexAIConfig};
use hmai::sim::{simulate, SimOptions};

/// Skip (with a message) when PJRT artifacts are unavailable.
fn rt() -> Option<Arc<Runtime>> {
    match Runtime::load_default() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping runtime e2e test: {e:#}");
            None
        }
    }
}

/// Independent rust reference of the Q-network forward pass:
/// x·W1+b1 → ReLU → ·W2+b2 → ReLU → ·W3+b3.  Must match the compiled
/// Pallas/JAX path bit-for-bit up to f32 accumulation order.
fn reference_forward(params: &Params, x: &[f32], meta: &hmai::runtime::Meta) -> Vec<f32> {
    let t = params.tensors();
    let (w1, b1, w2, b2, w3, b3) = (&t[0], &t[1], &t[2], &t[3], &t[4], &t[5]);
    let matvec = |x: &[f32], w: &[f32], b: &[f32], i: usize, o: usize, relu: bool| {
        let mut y = vec![0.0f32; o];
        for c in 0..o {
            // f64 accumulation: tolerance below absorbs ordering effects.
            let mut acc = b[c] as f64;
            for r in 0..i {
                acc += x[r] as f64 * w[r * o + c] as f64;
            }
            y[c] = if relu { (acc as f32).max(0.0) } else { acc as f32 };
        }
        y
    };
    let h1 = matvec(x, w1, b1, meta.in_dim, meta.h1, true);
    let h2 = matvec(&h1, w2, b2, meta.h1, meta.h2, true);
    matvec(&h2, w3, b3, meta.h2, meta.out_dim, false)
}

#[test]
fn compiled_qnet_matches_rust_reference() {
    let Some(rt) = rt() else { return };
    let params = rt.init_params(11).unwrap();
    // A few structured states, not just noise.
    let mut states: Vec<Vec<f32>> = Vec::new();
    states.push(vec![0.0; rt.meta.in_dim]);
    states.push(vec![1.0; rt.meta.in_dim]);
    let mut ramp = vec![0.0f32; rt.meta.in_dim];
    for (i, v) in ramp.iter_mut().enumerate() {
        *v = (i as f32 / 134.0).sin().abs();
    }
    states.push(ramp);
    for x in &states {
        let compiled = rt.infer(&params, x).unwrap();
        let reference = reference_forward(&params, x, &rt.meta);
        for (c, r) in compiled.iter().zip(&reference) {
            assert!(
                (c - r).abs() <= 1e-3 * (1.0 + r.abs()),
                "compiled {c} vs reference {r}"
            );
        }
    }
}

#[test]
fn train_step_matches_sgd_direction() {
    // After one compiled train step on a batch whose TD target exceeds
    // Q(s,a), Q(s,a) must move toward the target (plain SGD property).
    let Some(rt) = rt() else { return };
    let params = rt.init_params(3).unwrap();
    let targ = params.clone();
    let mut batch = TrainBatch::zeros(&rt.meta);
    for (i, v) in batch.s.iter_mut().enumerate() {
        *v = ((i * 7) % 19) as f32 / 19.0;
    }
    batch.s2.copy_from_slice(&batch.s);
    for a in batch.a.iter_mut() {
        *a = 2;
    }
    for r in batch.r.iter_mut() {
        *r = 5.0; // large positive reward pushes the target above Q
    }
    for d in batch.done.iter_mut() {
        *d = 1.0; // y = r exactly
    }
    let q_before = rt.infer(&params, &batch.s[..rt.meta.in_dim].to_vec()).unwrap()[2];
    let (new_params, loss) = rt.train_step(&params, &targ, &batch).unwrap();
    let q_after = rt.infer(&new_params, &batch.s[..rt.meta.in_dim].to_vec()).unwrap()[2];
    assert!(loss > 0.0);
    assert!(
        q_after > q_before,
        "Q(s, a=2) must move toward target 5.0: {q_before} -> {q_after}"
    );
}

#[test]
fn gamma_zero_done_batch_converges_to_reward() {
    // With done=1 everywhere the TD target is exactly r; repeated steps on
    // the same batch must drive Q(s,a) to r.
    let Some(rt) = rt() else { return };
    let mut params = rt.init_params(5).unwrap();
    let targ = params.clone();
    let mut batch = TrainBatch::zeros(&rt.meta);
    for (i, v) in batch.s.iter_mut().enumerate() {
        *v = ((i * 13) % 17) as f32 / 17.0;
    }
    batch.s2.copy_from_slice(&batch.s);
    for a in batch.a.iter_mut() {
        *a = 0;
    }
    for r in batch.r.iter_mut() {
        *r = -1.5;
    }
    for d in batch.done.iter_mut() {
        *d = 1.0;
    }
    let mut loss = f32::INFINITY;
    for _ in 0..200 {
        let (p, l) = rt.train_step(&params, &targ, &batch).unwrap();
        params = p;
        loss = l;
    }
    assert!(loss < 0.05, "loss should converge to ~0, got {loss}");
    let q = rt.infer(&params, &batch.s[..rt.meta.in_dim].to_vec()).unwrap()[0];
    assert!((q - (-1.5)).abs() < 0.3, "Q -> r: got {q}");
}

#[test]
fn trained_agent_roundtrips_through_checkpoint_identically() {
    let Some(rt) = rt() else { return };
    let queue = queue_for(Area::Urban, 40.0, 0, DeadlineMode::Rss, 21);
    let platform = Platform::hmai();

    // Short in-process training.
    let cfg = FlexAIConfig { seed: 21, min_replay: 64, ..Default::default() };
    let mut agent = FlexAI::new(rt.clone(), cfg.clone()).unwrap();
    agent.set_training(true);
    simulate(&queue, &platform, &mut agent, SimOptions::default());
    agent.end_episode();
    agent.set_training(false);

    let dir = std::env::temp_dir().join("hmai_e2e_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("agent.json");
    checkpoint::save(&agent, &path).unwrap();
    let mut restored = checkpoint::load(rt, &path, cfg).unwrap();

    // Greedy decisions of original and restored agents must be identical.
    let ra = simulate(&queue, &platform, &mut agent, SimOptions { record_tasks: true });
    let rb = simulate(&queue, &platform, &mut restored, SimOptions { record_tasks: true });
    assert_eq!(ra.records.len(), rb.records.len());
    for (a, b) in ra.records.iter().zip(&rb.records) {
        assert_eq!(a.accel, b.accel, "task {}", a.task_id);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn flexai_safety_shield_improves_or_preserves_stm_rate() {
    let Some(rt) = rt() else { return };
    let queue = queue_for(Area::Urban, 50.0, 0, DeadlineMode::Rss, 33);
    let platform = Platform::hmai();
    let run = |shield: bool| {
        let cfg = FlexAIConfig { seed: 33, safety_shield: shield, ..Default::default() };
        let mut agent = FlexAI::new(rt.clone(), cfg).unwrap();
        agent.set_training(false);
        simulate(&queue, &platform, &mut agent, SimOptions::default()).summary
    };
    let with = run(true);
    let without = run(false);
    assert!(
        with.stm_rate() >= without.stm_rate(),
        "shield {} !>= pure {}",
        with.stm_rate(),
        without.stm_rate()
    );
}
