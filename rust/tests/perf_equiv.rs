//! Scheduler-equivalence suite for the burst-scheduling hot-path overhaul:
//! whole sweeps run through the optimized registry and through
//! `sched::reference` (the pre-overhaul algorithms: full `ShadowState`
//! clones, global rescans, per-genome best-case folds) must produce equal
//! `SweepSummary::fingerprint`s — the optimizations provably change no
//! result bits.
//!
//! Coverage: every registered (non-FlexAI) scheduler, on a healthy
//! scenario (`urban-rush`), a fault-event scenario (`accel-failure` with
//! `--events` semantics), and a mixed-core-size platform
//! (`so:4@2x,si:4,mm:3@0.5x`).

use hmai::engine::Engine;
use hmai::metrics::summary::SweepSummary;
use hmai::plan::ExperimentPlan;
use hmai::sched::reference::reference_registry;
use hmai::sched::{Registry, SchedulerSpec};

/// Every registered scheduler (FlexAI needs a PJRT runtime, so it is the
/// one spec the base registry cannot build — both registries share that
/// gap, and its decision path is untouched by this overhaul).
fn all_specs() -> Vec<SchedulerSpec> {
    [
        SchedulerSpec::MinMin,
        SchedulerSpec::Ata,
        SchedulerSpec::Edp,
        SchedulerSpec::Ga,
        SchedulerSpec::Sa,
        SchedulerSpec::Worst,
        SchedulerSpec::RoundRobin,
        SchedulerSpec::Random,
    ]
    .to_vec()
}

fn fingerprints(plan: &ExperimentPlan, events: bool) -> (u64, u64) {
    let optimized = Registry::new();
    let reference = reference_registry();
    let run = |reg: &Registry| -> SweepSummary {
        Engine::new(reg).jobs(2).events(events).sweep_streaming(plan).unwrap()
    };
    (run(&optimized).fingerprint(), run(&reference).fingerprint())
}

#[test]
fn optimized_matches_reference_on_urban_rush() {
    let plan = ExperimentPlan::new()
        .scenarios(["urban-rush"])
        .distances([40.0])
        .schedulers(all_specs())
        .seed(7);
    let (fast, slow) = fingerprints(&plan, false);
    assert_eq!(fast, slow, "healthy-platform sweep drifted");
}

#[test]
fn optimized_matches_reference_under_platform_faults() {
    // accel-failure declares a mid-route Fail/Recover window; with events
    // on, schedulers route around the outage — the incremental Min-Min
    // cache and the RolloutCtx dead-slot pricing must reproduce the
    // reference decisions exactly through the failure and the recovery.
    let plan = ExperimentPlan::new()
        .scenarios(["accel-failure"])
        .distances([60.0])
        .schedulers(all_specs())
        .seed(11);
    let (fast, slow) = fingerprints(&plan, true);
    assert_eq!(fast, slow, "fault-event sweep drifted");
    // Sanity: the same plan without events differs (the outage is real).
    let (no_events, _) = fingerprints(&plan, false);
    assert_ne!(fast, no_events, "events must change the outcome");
}

#[test]
fn optimized_matches_reference_on_mixed_core_platform() {
    // Mixed core sizes give every slot distinct cost rows — the sharpest
    // test of the per-burst cost-row caches (and of Half-core tie-breaks).
    let plan = ExperimentPlan::new()
        .platforms(["so:4@2x,si:4,mm:3@0.5x"])
        .distances([40.0])
        .schedulers(all_specs())
        .seed(3);
    let (fast, slow) = fingerprints(&plan, false);
    assert_eq!(fast, slow, "mixed-core sweep drifted");
}

#[test]
fn reference_sweep_rows_align_one_to_one() {
    // Beyond the fingerprint: identical trial order and per-field bits on
    // a small sweep, so a future drift points at the exact run.
    let plan = ExperimentPlan::new()
        .scenarios(["urban-rush"])
        .distances([40.0])
        .schedulers([SchedulerSpec::MinMin, SchedulerSpec::Ga, SchedulerSpec::Sa])
        .seed(5);
    let fast = Engine::new(&Registry::new()).run(&plan).unwrap();
    let slow = Engine::new(&reference_registry()).run(&plan).unwrap();
    assert_eq!(fast.len(), slow.len());
    for (a, b) in fast.iter().zip(&slow) {
        assert_eq!(a.trial.id, b.trial.id);
        let (x, y) = (&a.summary, &b.summary);
        assert_eq!(x.tasks, y.tasks, "trial {}", a.trial.id);
        assert_eq!(x.tasks_met, y.tasks_met, "trial {}", a.trial.id);
        for (fa, fb, field) in [
            (x.energy_j, y.energy_j, "energy_j"),
            (x.makespan_s, y.makespan_s, "makespan_s"),
            (x.wait_s, y.wait_s, "wait_s"),
            (x.compute_s, y.compute_s, "compute_s"),
            (x.r_balance, y.r_balance, "r_balance"),
            (x.ms_total, y.ms_total, "ms_total"),
            (x.gvalue, y.gvalue, "gvalue"),
            (x.mean_response_s, y.mean_response_s, "mean_response_s"),
            (x.max_response_s, y.max_response_s, "max_response_s"),
        ] {
            assert_eq!(fa.to_bits(), fb.to_bits(), "trial {} field {field}", a.trial.id);
        }
        // The streaming tail histograms are part of the result too.
        assert_eq!(
            x.content_hash(),
            y.content_hash(),
            "trial {} content hash (histograms?)",
            a.trial.id
        );
    }
}
