//! Golden contract of the core-size parameterization:
//!
//! 1. `CoreSize::Std` is **bit-identical** to the pre-parameterization
//!    cost model, end to end — the cached matrix, the uncached path, and a
//!    whole simulation on a `Std`-spec platform (the existing
//!    `tests/sweep.rs` / `tests/scenario.rs` / `tests/stream.rs`
//!    fingerprints pin the same property against the seed history).
//! 2. Monotonicity across sizes: for every (accelerator, model) pair,
//!    Half is slower than Std is slower than Double; energy stays in a
//!    sane band (the dataflow, not the provisioning, owns energy).
//! 3. The sized platform-spec syntax round-trips.

use hmai::accel::{
    cost, cost_sized, peak_tops, peak_tops_sized, task_cost, task_cost_sized, AccelKind,
    CoreSize, ALL_ACCELS, ALL_SIZES,
};
use hmai::engine::Engine;
use hmai::plan::ExperimentPlan;
use hmai::platform::Platform;
use hmai::sched::{Registry, SchedulerSpec};
use hmai::workload::ALL_MODELS;

/// The Table 8 FPS goldens (the values `accel::cost` reproduced before the
/// size parameterization, pinned within calibration rounding).  If the
/// `Std` path drifts, this fails even though `cost` delegates to
/// `cost_sized`.
const TABLE8_FPS: [(usize, usize, f64); 9] = [
    (0, 0, 170.37), // SconvOD x YOLO
    (1, 0, 132.54),
    (2, 0, 149.32),
    (0, 1, 74.99),
    (1, 1, 82.94),
    (2, 1, 82.57),
    (0, 2, 352.69),
    (1, 2, 350.34),
    (2, 2, 500.54),
];

#[test]
fn std_matrix_is_bit_identical_across_every_entry_point() {
    for a in ALL_ACCELS {
        for m in ALL_MODELS {
            let cached = cost(a, m);
            let sized = cost_sized(a, m, CoreSize::Std);
            let uncached = task_cost(a, m);
            let uncached_sized = task_cost_sized(a, m, CoreSize::Std);
            for (x, y) in [
                (cached.time_s, sized.time_s),
                (cached.energy_j, sized.energy_j),
                (cached.cycles, sized.cycles),
                (cached.utilization, sized.utilization),
                (cached.time_s, uncached.time_s),
                (cached.energy_j, uncached.energy_j),
                (uncached.time_s, uncached_sized.time_s),
                (uncached.energy_j, uncached_sized.energy_j),
            ] {
                assert_eq!(x.to_bits(), y.to_bits(), "{a:?} {m:?}");
            }
        }
    }
    assert_eq!(peak_tops().to_bits(), peak_tops_sized(CoreSize::Std).to_bits());
}

#[test]
fn std_matrix_still_reproduces_table8() {
    for (ai, mi, fps) in TABLE8_FPS {
        let a = ALL_ACCELS[ai];
        let m = ALL_MODELS[mi];
        let ours = cost_sized(a, m, CoreSize::Std).fps();
        assert!((ours / fps - 1.0).abs() < 1e-3, "{a:?} {m:?}: {ours} vs {fps}");
    }
}

#[test]
fn half_is_slower_and_double_is_faster_per_pair() {
    for a in ALL_ACCELS {
        for m in ALL_MODELS {
            let half = cost_sized(a, m, CoreSize::Half);
            let std = cost_sized(a, m, CoreSize::Std);
            let double = cost_sized(a, m, CoreSize::Double);
            // Strict across the 4x span; adjacent sizes may tie on
            // pathological tilings but never invert.
            assert!(half.time_s > double.time_s, "{a:?} {m:?}");
            assert!(half.time_s >= std.time_s, "{a:?} {m:?}: half faster than std");
            assert!(std.time_s >= double.time_s, "{a:?} {m:?}: std faster than double");
            // Utilization stays physical at every size.
            for c in [half, std, double] {
                assert!(c.utilization > 0.0 && c.utilization <= 1.0, "{a:?} {m:?}");
                assert!(c.energy_j > 0.0);
            }
            // Energy ordering sane: provisioning shifts per-inference
            // energy by a bounded factor (the dataflow, not the size,
            // owns the energy profile — only stall re-fetches and the
            // affinity anchor move with the array).
            for c in [half, double] {
                let r = c.energy_j / std.energy_j;
                assert!((0.4..2.5).contains(&r), "{a:?} {m:?}: energy ratio {r}");
            }
            // Sustained power rises with the MAC budget.
            assert!(half.power_w() < double.power_w(), "{a:?} {m:?}");
        }
    }
}

#[test]
fn std_mix_spec_sweeps_bit_identical_to_legacy_spec() {
    // "so:4,si:4,mm:3" and "4,4,3" describe the same machine; every
    // deterministic summary field of a real sweep must agree bit-for-bit
    // (platform *names* differ, so fingerprints are compared field-wise).
    let reg = Registry::new();
    let run = |spec: &str| {
        let plan = ExperimentPlan::new()
            .distances([60.0])
            .platform(spec.to_string())
            .schedulers([SchedulerSpec::MinMin, SchedulerSpec::Sa])
            .seed(11);
        Engine::new(&reg).run(&plan).unwrap()
    };
    let legacy = run("4,4,3");
    let mix = run("so:4,si:4,mm:3");
    assert_eq!(legacy.len(), mix.len());
    for (a, b) in legacy.iter().zip(&mix) {
        assert_eq!(a.summary.tasks, b.summary.tasks);
        assert_eq!(a.summary.tasks_met, b.summary.tasks_met);
        for (x, y) in [
            (a.summary.energy_j, b.summary.energy_j),
            (a.summary.makespan_s, b.summary.makespan_s),
            (a.summary.wait_s, b.summary.wait_s),
            (a.summary.compute_s, b.summary.compute_s),
            (a.summary.r_balance, b.summary.r_balance),
            (a.summary.ms_total, b.summary.ms_total),
            (a.summary.gvalue, b.summary.gvalue),
            (a.summary.mean_response_s, b.summary.mean_response_s),
            (a.summary.max_response_s, b.summary.max_response_s),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "trial {}", a.trial.id);
        }
    }
}

#[test]
fn sized_platform_sweeps_are_deterministic_and_jobs_invariant() {
    // Mixed-size platforms inherit the whole determinism contract.
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .distances([50.0])
        .platforms(["so:2@2x,si:2,mm:2@0.5x", "so:1@0.5x,si:1@0.5x,mm:1@0.5x"])
        .schedulers([SchedulerSpec::MinMin, SchedulerSpec::Random])
        .seed(7);
    let (_, seq) = Engine::new(&reg).jobs(1).sweep(&plan).unwrap();
    let (_, par) = Engine::new(&reg).jobs(3).sweep(&plan).unwrap();
    assert_eq!(seq.fingerprint(), par.fingerprint());
    // And the sizes actually matter: an all-half platform differs from an
    // all-std platform of the same counts.
    let half = ExperimentPlan::new()
        .distances([50.0])
        .platform("so:2@0.5x,si:2@0.5x,mm:2@0.5x")
        .scheduler(SchedulerSpec::MinMin)
        .seed(7);
    let std = ExperimentPlan::new()
        .distances([50.0])
        .platform("so:2,si:2,mm:2")
        .scheduler(SchedulerSpec::MinMin)
        .seed(7);
    let (h, _) = Engine::new(&reg).sweep(&half).unwrap();
    let (s, _) = Engine::new(&reg).sweep(&std).unwrap();
    assert!(
        h[0].summary.compute_s > s[0].summary.compute_s,
        "half cores must stretch compute: {} vs {}",
        h[0].summary.compute_s,
        s[0].summary.compute_s
    );
}

#[test]
fn spec_syntax_round_trips_through_the_plan_layer() {
    let spec = "so:4@2x,si:4,mm:3@0.5x";
    let plan = ExperimentPlan::new()
        .distances([40.0])
        .platform(spec)
        .scheduler(SchedulerSpec::RoundRobin)
        .seed(3);
    let trials = plan.trials().unwrap();
    let p = trials[0].platform().unwrap();
    assert_eq!(p.len(), 11);
    assert_eq!(p.count_of_sized(AccelKind::SconvOD, CoreSize::Double), 4);
    assert_eq!(p.count_of_sized(AccelKind::MconvMC, CoreSize::Half), 3);
    // Bad specs are rejected at plan expansion with a pointed message.
    let bad = ExperimentPlan::new()
        .distances([40.0])
        .platform("4,x,3")
        .scheduler(SchedulerSpec::RoundRobin);
    let err = format!("{:#}", bad.trials().unwrap_err());
    assert!(err.contains("component 2") && err.contains("'x'"), "{err}");
}

#[test]
fn all_sizes_are_enumerated_in_order() {
    assert_eq!(ALL_SIZES.map(|s| s.index()), [0, 1, 2]);
    assert_eq!(ALL_SIZES.map(|s| s.macs()), [4096, 8192, 16384]);
    let p = Platform::try_parse("so:1@0.5x,so:1,so:1@2x").unwrap();
    assert_eq!(p.len(), 3);
    assert!((p.peak_tops() - 3.5 * peak_tops()).abs() < 1e-9);
}
