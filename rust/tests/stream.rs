//! Golden equivalence suite for the streaming simulation core: the `Sim`
//! stepper (and the observer-driven `simulate()` built on it) must
//! reproduce the one-shot results bit-for-bit for every scenario
//! archetype (`f64::to_bits` identity, same fingerprints `tests/scenario.rs`
//! pins), and the new fault archetypes must be `--jobs`-invariant with
//! platform events enabled while never assigning work to a failed
//! accelerator.

use hmai::engine::Engine;
use hmai::env::scenario;
use hmai::env::taskgen::DeadlineMode;
use hmai::metrics::summary::RunSummary;
use hmai::metrics::NormScales;
use hmai::plan::ExperimentPlan;
use hmai::platform::Platform;
use hmai::sched::{Registry, SchedulerSpec};
use hmai::sim::{simulate, RecordCollector, Sim, SimObserver, SimOptions};

/// Assert two run summaries are equal down to the last mantissa bit.
fn assert_summaries_bit_identical(a: &RunSummary, b: &RunSummary, ctx: &str) {
    assert_eq!(a.tasks, b.tasks, "{ctx}: tasks");
    assert_eq!(a.tasks_met, b.tasks_met, "{ctx}: tasks_met");
    for (x, y, field) in [
        (a.energy_j, b.energy_j, "energy_j"),
        (a.makespan_s, b.makespan_s, "makespan_s"),
        (a.wait_s, b.wait_s, "wait_s"),
        (a.compute_s, b.compute_s, "compute_s"),
        (a.r_balance, b.r_balance, "r_balance"),
        (a.ms_total, b.ms_total, "ms_total"),
        (a.gvalue, b.gvalue, "gvalue"),
        (a.mean_response_s, b.mean_response_s, "mean_response_s"),
        (a.max_response_s, b.max_response_s, "max_response_s"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {field} ({x} vs {y})");
    }
}

#[test]
fn stepper_matches_simulate_for_every_archetype() {
    let reg = Registry::new();
    let platform = Platform::hmai();
    for name in scenario::names() {
        let arch = scenario::find(&name).unwrap();
        let q = arch.queue_for(100.0, 0, DeadlineMode::Rss, 42);

        let mut s1 = reg.build_by_name("minmin", 1).unwrap();
        let oneshot = simulate(&q, &platform, s1.as_mut(), SimOptions { record_tasks: true });

        let mut s2 = reg.build_by_name("minmin", 1).unwrap();
        let scales = NormScales::for_queue(&q, &platform);
        let mut sim = Sim::new(&q, &platform, scales);
        let mut collector = RecordCollector::with_capacity(q.len());
        let mut bursts = 0u64;
        while let Some(b) = sim.step(s2.as_mut()) {
            bursts += 1;
            for (task, a) in b.tasks.iter().zip(b.applied.iter()) {
                collector.on_task(task, a);
            }
        }
        let stepped = sim.into_result(&s2.name());

        assert_eq!(oneshot.bursts, bursts, "{name}: burst count");
        assert_summaries_bit_identical(&oneshot.summary, &stepped.summary, &name);
        let recs = collector.into_records();
        assert_eq!(recs.len(), oneshot.records.len(), "{name}: record count");
        for (x, y) in recs.iter().zip(&oneshot.records) {
            assert_eq!(x.task_id, y.task_id, "{name}");
            assert_eq!(x.accel, y.accel, "{name}: task {}", x.task_id);
            assert_eq!(x.release_s.to_bits(), y.release_s.to_bits(), "{name}");
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits(), "{name}");
            assert_eq!(x.response_s.to_bits(), y.response_s.to_bits(), "{name}");
        }
    }
}

#[test]
fn fault_archetypes_are_jobs_invariant_with_events() {
    let reg = Registry::new();
    for name in ["accel-failure", "thermal-throttle"] {
        let plan = ExperimentPlan::new()
            .scenarios([name.to_string()])
            .distances([60.0, 90.0])
            .schedulers([SchedulerSpec::MinMin, SchedulerSpec::RoundRobin])
            .seed(42);
        let seq = Engine::new(&reg).events(true).jobs(1).sweep_streaming(&plan).unwrap();
        for jobs in [2, 4] {
            let par = Engine::new(&reg).events(true).jobs(jobs).sweep_streaming(&plan).unwrap();
            assert_eq!(
                seq.fingerprint(),
                par.fingerprint(),
                "{name}: fingerprint drifted at jobs={jobs}"
            );
        }
        // Events change the outcome relative to the event-free run of the
        // same archetype (otherwise the fault never reached the platform).
        let off = Engine::new(&reg).jobs(1).sweep_streaming(&plan).unwrap();
        assert_ne!(seq.fingerprint(), off.fingerprint(), "{name}: events were a no-op");
    }
}

#[test]
fn no_work_lands_on_a_failed_accel_for_any_scheduler() {
    // Every state-aware *and* state-blind baseline must route around the
    // accel-failure outage window.
    let reg = Registry::new();
    let arch = scenario::find("accel-failure").unwrap();
    for sched in ["minmin", "ata", "edp", "sa", "ga", "rr", "random", "worst"] {
        let plan = ExperimentPlan::new()
            .scenarios(["accel-failure"])
            .distances([60.0])
            .schedulers([SchedulerSpec::parse(sched).unwrap()])
            .seed(11);
        let trials = plan.trials().unwrap();
        let trial = &trials[0];
        let r = Engine::new(&reg)
            .events(true)
            .sim_options(SimOptions { record_tasks: true })
            .run_trial(trial)
            .unwrap();
        let dur = trial.queue().route_duration_s;
        let evts = arch.platform_events(dur);
        let (t_fail, t_rec) = (evts[0].at_s + 1e-6, evts[1].at_s - 1e-6);
        let window: Vec<_> = r
            .records
            .iter()
            .filter(|x| x.release_s >= t_fail && x.release_s < t_rec)
            .collect();
        assert!(!window.is_empty(), "{sched}: empty outage window");
        assert!(
            window.iter().all(|x| x.accel != 0),
            "{sched}: assigned the failed accelerator inside the outage"
        );
        // Traffic returns after recovery (outside the window the accel is
        // a normal member of the platform again) — guaranteed for the
        // cycling scheduler, spot-checked here.
        if sched == "rr" {
            assert!(r.records.iter().any(|x| x.release_s >= t_rec + 1e-6 && x.accel == 0));
        }
    }
}

#[test]
fn outage_on_a_single_accel_platform_drops_tasks_then_recovers() {
    // Degenerate platform: one accelerator, so during the accel-failure
    // outage every scheduler fallback must dispatch to the dead slot.
    // Those tasks are lost (infinite response, missed deadline, MS = -1)
    // but the FIFO must not be poisoned: after the Recover event the
    // accelerator serves new work with finite responses and the summary
    // stays finite.
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .scenarios(["accel-failure"])
        .distances([60.0])
        .platform("1,0,0")
        .scheduler(SchedulerSpec::RoundRobin)
        .seed(3);
    let trials = plan.trials().unwrap();
    let trial = &trials[0];
    let r = Engine::new(&reg)
        .events(true)
        .sim_options(SimOptions { record_tasks: true })
        .run_trial(trial)
        .unwrap();
    let dur = trial.queue().route_duration_s;
    let (t_fail, t_rec) = (0.35 * dur + 1e-6, 0.70 * dur - 1e-6);
    assert_eq!(r.records.len() as u64, r.summary.tasks, "every task is accounted for");
    let dropped: Vec<_> = r
        .records
        .iter()
        .filter(|x| x.release_s >= t_fail && x.release_s < t_rec)
        .collect();
    assert!(!dropped.is_empty(), "outage window must contain tasks");
    assert!(dropped
        .iter()
        .all(|x| !x.met_deadline && x.response_s.is_infinite() && x.ms == -1.0));
    let after: Vec<_> = r
        .records
        .iter()
        .filter(|x| x.release_s >= 0.70 * dur + 1e-6)
        .collect();
    assert!(!after.is_empty(), "route continues past recovery");
    assert!(
        after.iter().all(|x| x.response_s.is_finite()),
        "recovery must restore finite service"
    );
    for v in [
        r.summary.makespan_s,
        r.summary.compute_s,
        r.summary.mean_response_s,
        r.summary.max_response_s,
        r.summary.gvalue,
    ] {
        assert!(v.is_finite(), "summary field went non-finite: {v}");
    }
    // Mean response averages the *completed* tasks only — lost tasks are
    // excluded from numerator and denominator alike, so an outage cannot
    // make the platform look more responsive than its completed work.
    let finite: Vec<f64> =
        r.records.iter().map(|x| x.response_s).filter(|v| v.is_finite()).collect();
    let expect = finite.iter().sum::<f64>() / finite.len() as f64;
    assert_eq!(r.summary.mean_response_s.to_bits(), expect.to_bits());
}

#[test]
fn thermal_throttle_stretches_compute_in_the_derate_window() {
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .scenarios(["thermal-throttle"])
        .distances([60.0])
        .scheduler(SchedulerSpec::RoundRobin)
        .seed(13);
    let trials = plan.trials().unwrap();
    let trial = &trials[0];
    let run = |events: bool| {
        Engine::new(&reg)
            .events(events)
            .sim_options(SimOptions { record_tasks: true })
            .run_trial(trial)
            .unwrap()
    };
    let (with, without) = (run(true), run(false));
    let dur = trial.queue().route_duration_s;
    // Margins keep burst-boundary tasks (grouped within BURST_EPS of the
    // event instant) out of both comparison windows.
    let (t0, t1) = (0.25 * dur + 1e-6, 0.75 * dur - 1e-6);
    let before_window = 0.25 * dur - 1e-6;
    // RoundRobin keeps using the derated accelerators, so their in-window
    // compute times are exactly doubled relative to the event-free run.
    let mut compared = 0;
    for (a, b) in with.records.iter().zip(&without.records) {
        assert_eq!(a.task_id, b.task_id);
        if a.accel == b.accel && (a.accel == 0 || a.accel == 4) {
            if a.release_s >= t0 && a.release_s < t1 {
                assert!(
                    a.compute_s > b.compute_s * 1.5,
                    "task {}: {} !> 1.5x {}",
                    a.task_id,
                    a.compute_s,
                    b.compute_s
                );
                compared += 1;
            } else if a.release_s < before_window {
                assert_eq!(a.compute_s.to_bits(), b.compute_s.to_bits());
            }
        }
    }
    assert!(compared > 0, "no derated-window tasks compared");
    assert!(with.summary.wait_s > without.summary.wait_s, "derating must cost wait time");
}
