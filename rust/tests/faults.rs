//! Fault-injection integration suite: seeded MTBF/MTTR campaigns must be
//! deterministic and jobs-invariant; the link-failure archetype must
//! visibly tax communication on chiplet platforms while staying a bit-exact
//! no-op on monolithic ones; graceful degradation must be a bit-exact
//! pass-through on healthy platforms and never hurt the safety tier under
//! faults; and a panicking scheduler must cost exactly its own trials,
//! never the sweep.

use std::sync::Arc;

use hmai::engine::Engine;
use hmai::faults::FaultModel;
use hmai::metrics::summary::SweepSummary;
use hmai::plan::ExperimentPlan;
use hmai::sched::{BuildCtx, Registry, Scheduler, SchedulerSpec};

/// Aggregate safety-tier STM over every group of a sweep.
fn safety_stm(s: &SweepSummary) -> f64 {
    let tasks: u64 = s.groups.iter().map(|g| g.stats.sum_safety_tasks).sum();
    let met: u64 = s.groups.iter().map(|g| g.stats.sum_safety_met).sum();
    assert!(tasks > 0, "plan produced no safety-critical tasks");
    met as f64 / tasks as f64
}

#[test]
fn fault_campaign_is_deterministic_and_jobs_invariant() {
    // Same seed, same campaign — across the jobs split and across repeat
    // runs — and the campaign must actually perturb the sweep relative to
    // a fault-free run of the same plan.
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .platforms(["hmai", "hmai+mesh2x2"])
        .scenarios(["urban-rush"])
        .distances([60.0])
        .schedulers([SchedulerSpec::MinMin, SchedulerSpec::RoundRobin, SchedulerSpec::Edp])
        .seed(13);
    let model = FaultModel::default();
    let run = |jobs: usize| {
        Engine::new(&reg).jobs(jobs).faults(Some(model)).sweep_streaming(&plan).unwrap()
    };
    let a = run(1);
    assert_eq!(a.fingerprint(), run(3).fingerprint(), "jobs split changed a fault campaign");
    assert_eq!(a.fingerprint(), run(1).fingerprint(), "same seed must redraw the same faults");
    let clean = Engine::new(&reg).sweep_streaming(&plan).unwrap();
    assert_ne!(a.fingerprint(), clean.fingerprint(), "default fault model had no effect");
}

#[test]
fn link_failure_taxes_the_mesh_and_is_a_noop_on_mono() {
    let reg = Registry::new();
    let plan_for = |platform: &str, sched: SchedulerSpec| {
        ExperimentPlan::new()
            .platforms([platform])
            .scenarios(["link-failure"])
            .distances([60.0])
            .schedulers([sched])
            .seed(7)
    };
    // Monolithic platforms have no links: the archetype's events apply to
    // nothing, so events on/off must be bit-identical.
    let mono = |events: bool| {
        Engine::new(&reg)
            .events(events)
            .sweep_streaming(&plan_for("hmai", SchedulerSpec::MinMin))
            .unwrap()
            .fingerprint()
    };
    assert_eq!(mono(true), mono(false), "link events leaked into a mono platform");

    // On the mesh the severed link must change the run, and under Round-
    // Robin — which assigns cyclically, blind to communication cost — every
    // crossing of the dead link mid-window is rerouted over the long way
    // around, so the total comm delay strictly rises.
    let mesh = |events: bool, sched: SchedulerSpec| {
        let results =
            Engine::new(&reg).events(events).run(&plan_for("hmai+mesh2x2", sched)).unwrap();
        assert_eq!(results.len(), 1);
        results.into_iter().next().unwrap().summary
    };
    let mm_on = mesh(true, SchedulerSpec::MinMin);
    let mm_off = mesh(false, SchedulerSpec::MinMin);
    assert!(mm_on.comm_delay_s > 0.0, "mesh run moved no comm time");
    assert_ne!(
        mm_on.content_hash(),
        mm_off.content_hash(),
        "a severed link changed nothing on the mesh"
    );
    let rr_on = mesh(true, SchedulerSpec::RoundRobin);
    let rr_off = mesh(false, SchedulerSpec::RoundRobin);
    assert!(
        rr_on.comm_delay_s > rr_off.comm_delay_s,
        "rerouted crossings must cost more: {} !> {}",
        rr_on.comm_delay_s,
        rr_off.comm_delay_s
    );
}

#[test]
fn degrade_wrapper_is_bit_exact_pass_through_when_healthy() {
    // With no faults and no events every slot stays alive, so the
    // degradation wrapper must forward untouched — the whole sweep is
    // bit-identical with it on or off, on mono and chiplet platforms.
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .platforms(["hmai", "hmai+mesh2x2"])
        .scenarios(["urban-rush"])
        .distances([40.0])
        .schedulers([SchedulerSpec::MinMin, SchedulerSpec::RoundRobin, SchedulerSpec::Worst])
        .seed(5);
    let arm = |degrade: bool| {
        Engine::new(&reg).degrade(degrade).sweep_streaming(&plan).unwrap().fingerprint()
    };
    assert_eq!(arm(true), arm(false), "degradation wrapper changed a healthy sweep");
}

#[test]
fn degradation_never_hurts_the_safety_tier_under_faults() {
    // The degraded-comfort archetype keeps accelerator 0 down for most of
    // the route; shedding hopeless comfort (tracking) work must never cost
    // the safety tier — identical event timelines in both arms, so the
    // comparison isolates the policy.
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .platforms(["hmai"])
        .scenarios(["degraded-comfort"])
        .distances([60.0, 90.0])
        .schedulers([SchedulerSpec::MinMin])
        .seed(3);
    let arm = |degrade: bool| {
        Engine::new(&reg).events(true).degrade(degrade).sweep_streaming(&plan).unwrap()
    };
    let off = safety_stm(&arm(false));
    let on = safety_stm(&arm(true));
    assert!(on >= off, "degradation hurt the safety tier: {on} < {off}");
}

#[test]
fn a_panicking_scheduler_costs_its_trials_not_the_sweep() {
    // Re-register one canonical name with a factory that panics: its
    // trials must be counted as failed — moments untouched, sweep
    // completed, siblings unaffected — and the recovery path must be as
    // jobs-invariant as everything else.
    let mut reg = Registry::new();
    reg.register(
        "worst",
        Arc::new(|_: &SchedulerSpec, _: &BuildCtx| -> anyhow::Result<Box<dyn Scheduler>> {
            panic!("injected fault: scheduler construction blew up")
        }),
    );
    let plan = ExperimentPlan::new()
        .platforms(["hmai"])
        .scenarios(["urban-rush"])
        .distances([40.0])
        .schedulers([SchedulerSpec::MinMin, SchedulerSpec::Worst])
        .seed(2);
    let run = |jobs: usize| Engine::new(&reg).jobs(jobs).sweep_streaming(&plan).unwrap();
    let sweep = run(1);
    let group = |name: &str| {
        sweep.groups.iter().find(|g| g.key.scheduler == name).unwrap_or_else(|| {
            panic!("no '{name}' group in {:?}", sweep.groups.iter().map(|g| &g.key).collect::<Vec<_>>())
        })
    };
    let worst = group("WorstCase");
    assert_eq!(worst.stats.failed_trials, 1, "the panicked trial must be counted");
    assert_eq!(worst.trials(), 0, "a panicked trial must not fold moments");
    let minmin = group("Min-Min");
    assert_eq!(minmin.trials(), 1);
    assert_eq!(minmin.stats.failed_trials, 0);
    assert_eq!(run(2).fingerprint(), sweep.fingerprint(), "recovery path is jobs-variant");
}
