//! Multi-fidelity DSE acceptance suite (ISSUE 9):
//!   (a) `--fidelity exact` is bit-identical to a hand-rolled
//!       plan-and-fold evaluation of the same candidate set — the
//!       pre-fidelity evaluator's contract, preserved;
//!   (b) the default multi-fidelity mode reproduces the exact mode's
//!       Pareto frontier set on the tiny deterministic config while
//!       evaluating strictly fewer candidates at full fidelity, with the
//!       pool fully accounted (pruned + screened out + promoted);
//!   (c) rung promotion is deterministic run-to-run;
//!   (d) every reported row respects its own analytic bounds, and every
//!       pruned candidate really is dominated by an evaluated row.

use std::collections::BTreeSet;

use hmai::dse::{self, DseConfig, DseReport, FidelityMode, SearchMode};
use hmai::engine::Engine;
use hmai::env::taskgen::DeadlineMode;
use hmai::plan::ExperimentPlan;
use hmai::platform::Platform;
use hmai::sched::{Registry, SchedulerSpec};

/// The tiny deterministic config both fidelity modes are compared on:
/// small enough for full enumeration (no shortlist truncation), too small
/// for the HMAI anchor (area 11 > budget 2.5), so the candidate sets of
/// both modes are exactly `enumerate(2.5, None, _)`.
fn tiny(fidelity: FidelityMode) -> DseConfig {
    DseConfig {
        budget_area: 2.5,
        scenarios: vec!["urban-rush".to_string()],
        distances_m: vec![40.0],
        max_evals: 512,
        search: SearchMode::Full,
        jobs: 2,
        fidelity,
        ..DseConfig::default()
    }
}

fn frontier_specs(r: &DseReport) -> BTreeSet<String> {
    r.frontier_rows().map(|x| x.spec.clone()).collect()
}

/// Every row must obey its own analytic bounds — the soundness property
/// the pruner stands on (bounds are computed identically for pruned and
/// evaluated candidates).
fn assert_rows_respect_bounds(r: &DseReport) {
    for row in &r.rows {
        assert!(
            row.stm_rate <= row.stm_bound + 1e-9,
            "{}: realized STM {} above its upper bound {}",
            row.spec,
            row.stm_rate,
            row.stm_bound
        );
        assert!(
            row.energy_j >= row.energy_bound_j * (1.0 - 1e-9),
            "{}: realized energy {} below its lower bound {}",
            row.spec,
            row.energy_j,
            row.energy_bound_j
        );
    }
}

#[test]
fn exact_mode_is_bit_identical_to_a_hand_rolled_evaluator() {
    let cfg = tiny(FidelityMode::Exact);
    let report = dse::run(&cfg, &Registry::new()).unwrap();
    assert_eq!(report.fidelity, "exact");
    // Exact mode: pipeline inactive, every candidate a full row.
    assert_eq!(report.pruned(), 0);
    assert_eq!(report.screened_out, 0);
    assert_eq!(report.low_fidelity_evals, 0);
    assert_eq!(report.truncated, 0);

    // The candidate set is the full enumeration; re-evaluate it through
    // the public plan/engine API exactly the way the evaluator batches it
    // (one plan, all specs on the platform axis) and compare bits.
    let (mixes, over) = dse::enumerate(cfg.budget_area, None, cfg.max_evals);
    assert!(!over, "tiny budget must enumerate exhaustively");
    assert_eq!(report.evaluated, mixes.len());
    let plan = ExperimentPlan::new()
        .scenarios(cfg.scenarios.iter().cloned())
        .distances(cfg.distances_m.iter().copied())
        .deadline(cfg.deadline)
        .platforms(mixes.iter().map(|m| m.spec()))
        .scheduler(SchedulerSpec::MinMin)
        .seed(cfg.seed);
    let sweep = Engine::new(&Registry::new()).jobs(cfg.jobs).sweep_streaming(&plan).unwrap();
    for m in &mixes {
        let spec = m.spec();
        let name = Platform::try_parse(&spec).unwrap().name;
        let (mut met, mut tasks, mut n) = (0u64, 0u64, 0u64);
        let (mut ln_e, mut ln_t) = (0.0f64, 0.0f64);
        for g in sweep.groups.iter().filter(|g| g.key.platform == name) {
            met += g.stats.sum_tasks_met;
            tasks += g.stats.sum_tasks;
            n += g.stats.trials;
            ln_e += g.stats.sum_ln_energy;
            ln_t += g.stats.sum_ln_time;
        }
        assert!(n > 0, "no sweep rows for '{spec}'");
        let stm = if tasks == 0 { 1.0 } else { met as f64 / tasks as f64 };
        let energy = (ln_e / n as f64).exp();
        let time = (ln_t / n as f64).exp();
        let row = report.find(&spec).unwrap_or_else(|| panic!("'{spec}' missing from report"));
        assert_eq!(row.stm_rate.to_bits(), stm.to_bits(), "{spec} stm");
        assert_eq!(row.energy_j.to_bits(), energy.to_bits(), "{spec} energy");
        assert_eq!(row.time_s.to_bits(), time.to_bits(), "{spec} time");
    }
    assert_rows_respect_bounds(&report);
}

#[test]
fn default_multi_fidelity_reproduces_the_exact_frontier_with_fewer_full_evals() {
    let reg = Registry::new();
    let exact = dse::run(&tiny(FidelityMode::Exact), &reg).unwrap();
    let multi = dse::run(&tiny(FidelityMode::Multi), &reg).unwrap();
    assert_eq!(multi.fidelity, "multi");

    // The whole point: same frontier set, strictly fewer full evals.
    assert_eq!(
        frontier_specs(&exact),
        frontier_specs(&multi),
        "multi-fidelity mode changed the Pareto frontier set"
    );
    assert!(
        multi.evaluated < exact.evaluated,
        "multi mode must evaluate strictly fewer candidates at full fidelity \
         ({} vs {})",
        multi.evaluated,
        exact.evaluated
    );
    // Pipeline accounting: nothing leaves the pool uncounted.
    assert_eq!(multi.pool, exact.evaluated, "both modes search the same candidate pool");
    assert_eq!(multi.pool, multi.pruned() + multi.screened_out + multi.promoted);
    assert_eq!(multi.evaluated, multi.promoted, "every promoted candidate became a row");
    assert!(multi.low_fidelity_evals > 0, "screening must have run");
    assert_eq!(multi.rung_log.len(), 1, "default --rungs 1");
    assert_eq!(multi.rung_log[0].entered, multi.pool - multi.pruned());
    assert_eq!(multi.rung_log[0].promoted, multi.promoted);

    // Frontier rows come from full-fidelity evaluations: bit-identical to
    // the exact mode's rows for the same specs (group folds are invariant
    // to which other platforms shared the plan).
    for spec in frontier_specs(&multi) {
        let a = exact.find(&spec).unwrap();
        let b = multi.find(&spec).unwrap();
        assert_eq!(a.stm_rate.to_bits(), b.stm_rate.to_bits(), "{spec} stm");
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{spec} energy");
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits(), "{spec} time");
    }
    assert_rows_respect_bounds(&multi);
}

#[test]
fn rung_promotion_is_deterministic() {
    let reg = Registry::new();
    let cfg = DseConfig { rungs: 2, keep_frac: 0.4, ..tiny(FidelityMode::Multi) };
    let a = dse::run(&cfg, &reg).unwrap();
    let b = dse::run(&cfg, &reg).unwrap();
    assert_eq!(a.rung_log.len(), 2);
    assert_eq!(a.rung_log, b.rung_log, "rung accounting differs run-to-run");
    assert_eq!(a.evaluated, b.evaluated);
    assert_eq!(a.pruned(), b.pruned());
    assert_eq!(a.screened_out, b.screened_out);
    let specs = |r: &DseReport| r.rows.iter().map(|x| x.spec.clone()).collect::<Vec<_>>();
    assert_eq!(specs(&a), specs(&b), "promoted candidate set differs run-to-run");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.stm_rate.to_bits(), rb.stm_rate.to_bits(), "{}", ra.spec);
        assert_eq!(ra.energy_j.to_bits(), rb.energy_j.to_bits(), "{}", ra.spec);
    }
    // The two rungs ratchet: the later rung screens a superset fraction of
    // the route and never re-admits candidates.
    assert!(a.rung_log[0].route_frac < a.rung_log[1].route_frac);
    assert!(a.rung_log[1].entered == a.rung_log[0].promoted);
}

#[test]
fn pruning_accounting_is_sound_when_an_anchor_row_exists() {
    // Budget 11 fits the HMAI anchor, which multi mode evaluates *first*
    // at full fidelity — giving the bound pruner a reference row before
    // any pool candidate is simulated.
    let reg = Registry::new();
    let cfg = DseConfig {
        budget_area: 11.0,
        max_evals: 32,
        ..tiny(FidelityMode::Multi)
    };
    let report = dse::run(&cfg, &reg).unwrap();
    let hmai_spec = dse::Mix::hmai_std().spec();
    assert!(report.find(&hmai_spec).is_some(), "anchor must be evaluated at full fidelity");
    // Accounting holds even with the anchor overlapping the pool (the
    // shortlist may or may not re-list it — either way it is counted).
    assert_eq!(report.pool, report.pruned() + report.screened_out + report.promoted);
    assert_rows_respect_bounds(&report);
    // Pruning soundness: every pruned candidate's *best case* is dominated
    // by some evaluated full-fidelity row, so it could never have joined
    // the frontier (domination is transitive).
    for p in &report.pruned_rows {
        assert!(
            report.rows.iter().any(|r| {
                r.stm_rate >= p.stm_bound
                    && r.energy_j <= p.energy_bound_j
                    && r.area <= p.area
                    && (r.stm_rate > p.stm_bound
                        || r.energy_j < p.energy_bound_j
                        || r.area < p.area)
            }),
            "pruned '{}' is not dominated by any evaluated row",
            p.spec
        );
        assert!(report.find(&p.spec).is_none(), "'{}' both pruned and evaluated", p.spec);
    }
}
