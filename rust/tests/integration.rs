//! Cross-module integration tests: environment → scheduler → simulator →
//! metrics, for every scheduler, plus the conservation and ordering
//! properties the figures rely on.

use hmai::config::EnvConfig;
use hmai::env::taskgen::TaskQueue;
use hmai::env::{Area, ALL_AREAS};
use hmai::harness;
use hmai::metrics::NormScales;
use hmai::platform::Platform;
use hmai::sched::{by_name, Scheduler, BASELINES};
use hmai::sim::{simulate, simulate_with_scales, SimOptions};

fn queue(area: Area, dist: f64, seed: u64) -> TaskQueue {
    harness::make_queues(&EnvConfig { area, distances_m: vec![dist], seed }).remove(0)
}

const ALL_SCHEDS: [&str; 8] = ["minmin", "ata", "edp", "ga", "sa", "worst", "rr", "random"];

#[test]
fn every_scheduler_processes_every_task_in_every_area() {
    for area in ALL_AREAS {
        let q = queue(area, 60.0, 9);
        let platform = Platform::hmai();
        for name in ALL_SCHEDS {
            let mut s = by_name(name, 3).unwrap();
            let r = simulate(&q, &platform, s.as_mut(), SimOptions { record_tasks: true });
            assert_eq!(r.summary.tasks as usize, q.len(), "{name} {area:?}");
            assert_eq!(r.records.len(), q.len(), "{name} {area:?}");
            // Conservation: every record's accel is in range; totals match.
            assert!(r.records.iter().all(|rec| rec.accel < platform.len()));
            let total_e: f64 = r.records.iter().map(|rec| rec.energy_j).sum();
            assert!(
                (total_e - r.summary.energy_j).abs() < 1e-6,
                "{name}: record energy {} vs summary {}",
                total_e,
                r.summary.energy_j
            );
            let met = r.records.iter().filter(|rec| rec.met_deadline).count() as u64;
            assert_eq!(met, r.summary.tasks_met, "{name}");
        }
    }
}

#[test]
fn summary_wait_equals_record_wait() {
    let q = queue(Area::Urban, 50.0, 1);
    let mut s = by_name("sa", 1).unwrap();
    let r = simulate(&q, &Platform::hmai(), s.as_mut(), SimOptions { record_tasks: true });
    let wait: f64 = r.records.iter().map(|rec| rec.wait_s).sum();
    assert!((wait - r.summary.wait_s).abs() < 1e-6);
}

#[test]
fn fixed_scales_reproduce_default_scales() {
    let q = queue(Area::Urban, 40.0, 2);
    let platform = Platform::hmai();
    let scales = NormScales::for_queue(&q, &platform);
    let mut a = by_name("minmin", 0).unwrap();
    let mut b = by_name("minmin", 0).unwrap();
    let ra = simulate(&q, &platform, a.as_mut(), SimOptions::default());
    let rb = simulate_with_scales(&q, &platform, b.as_mut(), SimOptions::default(), scales);
    assert_eq!(ra.summary.energy_j, rb.summary.energy_j);
    assert_eq!(ra.summary.gvalue, rb.summary.gvalue);
}

#[test]
fn worst_case_is_the_floor() {
    // The unscheduled worst case has the worst makespan and R_Balance of
    // all schedulers (the Fig. 12 floor).
    let q = queue(Area::Urban, 80.0, 3);
    let platform = Platform::hmai();
    let mut worst = by_name("worst", 0).unwrap();
    let wc = simulate(&q, &platform, worst.as_mut(), SimOptions::default());
    for name in ["minmin", "sa", "ata", "edp", "rr"] {
        let mut s = by_name(name, 0).unwrap();
        let r = simulate(&q, &platform, s.as_mut(), SimOptions::default());
        assert!(
            r.summary.makespan_s < wc.summary.makespan_s,
            "{name} makespan !< worst"
        );
        assert!(
            r.summary.r_balance > wc.summary.r_balance,
            "{name} balance !> worst"
        );
    }
}

#[test]
fn ata_leads_baselines_on_ms() {
    // Table 11 / §8.3: ATA is the only baseline optimized toward MS.
    let q = queue(Area::Urban, 80.0, 4);
    let platform = Platform::hmai();
    let run = |name: &str| {
        let mut s = by_name(name, 0).unwrap();
        simulate(&q, &platform, s.as_mut(), SimOptions::default()).summary
    };
    let ata = run("ata");
    for name in ["ga", "worst", "random"] {
        assert!(
            ata.ms_per_task() > run(name).ms_per_task(),
            "ATA MS !> {name}"
        );
    }
}

#[test]
fn larger_platform_reduces_waiting() {
    let q = queue(Area::Urban, 60.0, 5);
    let small = Platform::from_counts("small", 2, 2, 2);
    let large = Platform::from_counts("large", 8, 8, 6);
    let mut s1 = by_name("sa", 1).unwrap();
    let mut s2 = by_name("sa", 1).unwrap();
    let r_small = simulate(&q, &small, s1.as_mut(), SimOptions::default());
    let r_large = simulate(&q, &large, s2.as_mut(), SimOptions::default());
    assert!(r_large.summary.wait_s < r_small.summary.wait_s);
    assert!(r_large.summary.stm_rate() >= r_small.summary.stm_rate());
}

#[test]
fn harness_run_queues_resets_between_queues() {
    let env = EnvConfig { area: Area::Urban, distances_m: vec![40.0], seed: 6 };
    let q = harness::make_queues(&env).remove(0);
    let queues = vec![q.clone(), q]; // identical queues, stateful scheduler
    let platform = Platform::hmai();
    // A stateful scheduler (random) must produce identical summaries on
    // identical queues thanks to reset().
    let mut s = by_name("random", 11).unwrap();
    let rs = harness::run_queues(&queues, &platform, s.as_mut(), SimOptions::default());
    assert_eq!(rs[0].summary.energy_j, rs[1].summary.energy_j);
    assert_eq!(rs[0].summary.tasks_met, rs[1].summary.tasks_met);
}

#[test]
fn highway_queues_have_no_reverse_tasks() {
    let q = queue(Area::Highway, 300.0, 7);
    assert!(q
        .tasks
        .iter()
        .all(|t| t.scenario != hmai::env::Scenario::Reverse));
}

#[test]
fn stm_rate_is_monotone_in_deadline_slack() {
    // Scaling every safety time up can only improve STMRate.
    let mut q = queue(Area::Urban, 60.0, 8);
    let platform = Platform::hmai();
    let mut s = by_name("rr", 0).unwrap();
    let base = simulate(&q, &platform, s.as_mut(), SimOptions::default());
    for t in q.tasks.iter_mut() {
        t.safety_time_s *= 3.0;
    }
    let mut s2 = by_name("rr", 0).unwrap();
    let relaxed = simulate(&q, &platform, s2.as_mut(), SimOptions::default());
    assert!(relaxed.summary.stm_rate() >= base.summary.stm_rate());
}

#[test]
fn scheduler_trait_objects_are_nameable() {
    for name in BASELINES {
        let s: Box<dyn Scheduler> = by_name(name, 0).unwrap();
        assert!(!s.name().is_empty());
    }
}
