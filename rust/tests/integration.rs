//! Cross-module integration tests: environment → scheduler → simulator →
//! metrics, for every scheduler, plus the conservation and ordering
//! properties the figures rely on.

use hmai::env::taskgen::{DeadlineMode, TaskQueue};
use hmai::env::{Area, ALL_AREAS};
use hmai::metrics::NormScales;
use hmai::plan::queue_for;
use hmai::platform::Platform;
use hmai::sched::{baseline_names, Registry, Scheduler};
use hmai::sim::{simulate, simulate_with_scales, SimOptions};

fn queue(area: Area, dist: f64, seed: u64) -> TaskQueue {
    queue_for(area, dist, 0, DeadlineMode::Rss, seed)
}

fn build(reg: &Registry, name: &str, seed: u64) -> Box<dyn Scheduler> {
    reg.build_by_name(name, seed).unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

const ALL_SCHEDS: [&str; 8] = ["minmin", "ata", "edp", "ga", "sa", "worst", "rr", "random"];

#[test]
fn every_scheduler_processes_every_task_in_every_area() {
    let reg = Registry::new();
    for area in ALL_AREAS {
        let q = queue(area, 60.0, 9);
        let platform = Platform::hmai();
        for name in ALL_SCHEDS {
            let mut s = build(&reg, name, 3);
            let r = simulate(&q, &platform, s.as_mut(), SimOptions { record_tasks: true });
            assert_eq!(r.summary.tasks as usize, q.len(), "{name} {area:?}");
            assert_eq!(r.records.len(), q.len(), "{name} {area:?}");
            // Conservation: every record's accel is in range; totals match.
            assert!(r.records.iter().all(|rec| rec.accel < platform.len()));
            let total_e: f64 = r.records.iter().map(|rec| rec.energy_j).sum();
            assert!(
                (total_e - r.summary.energy_j).abs() < 1e-6,
                "{name}: record energy {} vs summary {}",
                total_e,
                r.summary.energy_j
            );
            let met = r.records.iter().filter(|rec| rec.met_deadline).count() as u64;
            assert_eq!(met, r.summary.tasks_met, "{name}");
        }
    }
}

#[test]
fn summary_wait_equals_record_wait() {
    let reg = Registry::new();
    let q = queue(Area::Urban, 50.0, 1);
    let mut s = build(&reg, "sa", 1);
    let r = simulate(&q, &Platform::hmai(), s.as_mut(), SimOptions { record_tasks: true });
    let wait: f64 = r.records.iter().map(|rec| rec.wait_s).sum();
    assert!((wait - r.summary.wait_s).abs() < 1e-6);
}

#[test]
fn fixed_scales_reproduce_default_scales() {
    let reg = Registry::new();
    let q = queue(Area::Urban, 40.0, 2);
    let platform = Platform::hmai();
    let scales = NormScales::for_queue(&q, &platform);
    let mut a = build(&reg, "minmin", 0);
    let mut b = build(&reg, "minmin", 0);
    let ra = simulate(&q, &platform, a.as_mut(), SimOptions::default());
    let rb = simulate_with_scales(&q, &platform, b.as_mut(), SimOptions::default(), scales);
    assert_eq!(ra.summary.energy_j, rb.summary.energy_j);
    assert_eq!(ra.summary.gvalue, rb.summary.gvalue);
}

#[test]
fn worst_case_is_the_floor() {
    // The unscheduled worst case has the worst makespan and R_Balance of
    // all schedulers (the Fig. 12 floor).
    let reg = Registry::new();
    let q = queue(Area::Urban, 80.0, 3);
    let platform = Platform::hmai();
    let mut worst = build(&reg, "worst", 0);
    let wc = simulate(&q, &platform, worst.as_mut(), SimOptions::default());
    for name in ["minmin", "sa", "ata", "edp", "rr"] {
        let mut s = build(&reg, name, 0);
        let r = simulate(&q, &platform, s.as_mut(), SimOptions::default());
        assert!(
            r.summary.makespan_s < wc.summary.makespan_s,
            "{name} makespan !< worst"
        );
        assert!(
            r.summary.r_balance > wc.summary.r_balance,
            "{name} balance !> worst"
        );
    }
}

#[test]
fn ata_leads_baselines_on_ms() {
    // Table 11 / §8.3: ATA is the only baseline optimized toward MS.
    let reg = Registry::new();
    let q = queue(Area::Urban, 80.0, 4);
    let platform = Platform::hmai();
    let run = |name: &str| {
        let mut s = build(&reg, name, 0);
        simulate(&q, &platform, s.as_mut(), SimOptions::default()).summary
    };
    let ata = run("ata");
    for name in ["ga", "worst", "random"] {
        assert!(
            ata.ms_per_task() > run(name).ms_per_task(),
            "ATA MS !> {name}"
        );
    }
}

#[test]
fn larger_platform_reduces_waiting() {
    let reg = Registry::new();
    let q = queue(Area::Urban, 60.0, 5);
    let small = Platform::from_counts("small", 2, 2, 2);
    let large = Platform::from_counts("large", 8, 8, 6);
    let mut s1 = build(&reg, "sa", 1);
    let mut s2 = build(&reg, "sa", 1);
    let r_small = simulate(&q, &small, s1.as_mut(), SimOptions::default());
    let r_large = simulate(&q, &large, s2.as_mut(), SimOptions::default());
    assert!(r_large.summary.wait_s < r_small.summary.wait_s);
    assert!(r_large.summary.stm_rate() >= r_small.summary.stm_rate());
}

#[test]
fn fresh_per_trial_construction_matches_reset_semantics() {
    // The engine builds a fresh scheduler per trial; the legacy harness
    // reused one instance with reset() between queues.  For seeded
    // schedulers both must agree, because reset() re-seeds from scratch.
    let reg = Registry::new();
    let q = queue(Area::Urban, 40.0, 6);
    let platform = Platform::hmai();
    for name in ["random", "ga", "sa", "rr"] {
        // Legacy style: one instance, reset between identical queues.
        let mut reused = build(&reg, name, 11);
        let r1 = simulate(&q, &platform, reused.as_mut(), SimOptions::default());
        reused.reset();
        let r2 = simulate(&q, &platform, reused.as_mut(), SimOptions::default());
        // Engine style: fresh instance per queue.
        let mut fresh = build(&reg, name, 11);
        let r3 = simulate(&q, &platform, fresh.as_mut(), SimOptions::default());
        assert_eq!(r1.summary.energy_j, r2.summary.energy_j, "{name} reset");
        assert_eq!(r1.summary.energy_j, r3.summary.energy_j, "{name} fresh");
        assert_eq!(r1.summary.tasks_met, r3.summary.tasks_met, "{name} fresh");
    }
}

#[test]
fn highway_queues_have_no_reverse_tasks() {
    let q = queue(Area::Highway, 300.0, 7);
    assert!(q
        .tasks
        .iter()
        .all(|t| t.scenario != hmai::env::Scenario::Reverse));
}

#[test]
fn stm_rate_is_monotone_in_deadline_slack() {
    // Scaling every safety time up can only improve STMRate.
    let reg = Registry::new();
    let mut q = queue(Area::Urban, 60.0, 8);
    let platform = Platform::hmai();
    let mut s = build(&reg, "rr", 0);
    let base = simulate(&q, &platform, s.as_mut(), SimOptions::default());
    for t in q.tasks.iter_mut() {
        t.safety_time_s *= 3.0;
    }
    let mut s2 = build(&reg, "rr", 0);
    let relaxed = simulate(&q, &platform, s2.as_mut(), SimOptions::default());
    assert!(relaxed.summary.stm_rate() >= base.summary.stm_rate());
}

#[test]
fn scheduler_trait_objects_are_nameable() {
    let reg = Registry::new();
    for name in baseline_names() {
        let s: Box<dyn Scheduler> = build(&reg, name, 0);
        assert!(!s.name().is_empty());
    }
}
