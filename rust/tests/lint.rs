//! Meta-test: the determinism/panic-safety linter runs over the crate's
//! own `src/` and must report zero violations — making `cargo test -q`
//! the gate that keeps the invariants from rotting (the CLI subcommand
//! and the CI JSON step are the other two enforcement paths; see
//! DESIGN.md "Determinism invariants & static analysis").

use std::path::Path;

#[test]
fn crate_source_is_lint_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = hmai::lint::lint_dir(&src).expect("lint walk over src/");
    // Sanity: the walk really covered the tree (the crate has far more
    // than 40 source files; a broken walk must not vacuously pass).
    assert!(
        report.files >= 40,
        "lint walked only {} files under {} — broken walk?",
        report.files,
        src.display()
    );
    assert!(report.lines > 5_000, "implausibly small line count: {}", report.lines);
    assert!(
        report.violations.is_empty(),
        "lint violations in the crate source:\n{}",
        report.render()
    );
}

#[test]
fn suppressions_stay_audited() {
    // Every suppression is a justified pragma at an audited site.  This
    // count only moves when someone adds or burns down an allowance —
    // both are deliberate, reviewed events, so pin it.
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = hmai::lint::lint_dir(&src).expect("lint walk over src/");
    assert_eq!(
        report.suppressed, 12,
        "suppression count drifted — update this pin alongside the pragma audit"
    );
}
