//! Randomized property tests (hand-rolled proptest style over the
//! deterministic `Rng`): coordinator invariants that must hold for *any*
//! task sequence, platform shape and scheduler decision stream.

use hmai::accel::{cost, ALL_ACCELS};
use hmai::env::route::{Route, RouteParams};
use hmai::env::taskgen::{self, Task};
use hmai::env::{Area, CameraGroup, Scenario, ALL_AREAS, ALL_GROUPS};
use hmai::metrics::NormScales;
use hmai::platform::{alloc, Platform};
use hmai::safety::ms::{matching_score, TaskCategory};
use hmai::safety::rss::safety_time;
use hmai::sim::ShadowState;
use hmai::util::rng::Rng;
use hmai::workload::{ModelKind, ALL_MODELS};

fn random_task(rng: &mut Rng, id: u32) -> Task {
    let model = ALL_MODELS[rng.below(3)];
    Task {
        id,
        group: ALL_GROUPS[rng.below(6)],
        cam_idx: rng.below(4) as u8,
        release_s: rng.range_f64(0.0, 10.0),
        model,
        category: if model.is_tracker() {
            TaskCategory::Tracking
        } else {
            TaskCategory::Detection
        },
        scenario: Scenario::GoStraight,
        safety_time_s: rng.range_f64(0.01, 2.0),
    }
}

fn random_platform(rng: &mut Rng) -> Platform {
    loop {
        let (so, si, mm) = (rng.below(5), rng.below(5), rng.below(5));
        if so + si + mm > 0 {
            return Platform::from_counts("rand", so, si, mm);
        }
    }
}

/// Invariant: for any random decision stream, the shadow state's clock and
/// queues are causally consistent and metrics are conserved.
#[test]
fn shadow_state_causality_under_random_streams() {
    let mut rng = Rng::new(0xfeed);
    for trial in 0..50 {
        let platform = random_platform(&mut rng);
        let mut state = ShadowState::new(&platform, NormScales::unit());
        let mut tasks: Vec<Task> = (0..60).map(|i| random_task(&mut rng, i)).collect();
        tasks.sort_by(|a, b| a.release_s.total_cmp(&b.release_s));

        let mut total_compute = 0.0;
        let mut total_energy = 0.0;
        let mut ms_sum = 0.0;
        for t in &tasks {
            state.advance(t.release_s);
            let a = rng.below(platform.len());
            let applied = state.apply(t, a);
            // Causality.
            assert!(applied.start_s >= t.release_s - 1e-12, "trial {trial}");
            assert!(applied.finish_s > applied.start_s);
            assert!(applied.wait_s >= 0.0);
            assert!((applied.response_s - (applied.wait_s + applied.compute_s)).abs() < 1e-9);
            // Cost-model consistency.
            let c = cost(platform.accels[a].kind, t.model);
            assert_eq!(applied.compute_s, c.time_s);
            assert_eq!(applied.energy_j, c.energy_j);
            // MS bounds.
            assert!((-1.0..=1.0).contains(&applied.ms));
            assert!((0.0..=1.0).contains(&applied.r_j));
            total_compute += applied.compute_s;
            total_energy += applied.energy_j;
            ms_sum += applied.ms;
        }
        // Conservation across per-accelerator metrics.
        let m = &state.metrics;
        let busy: f64 = m.per_accel.iter().map(|a| a.busy_s).sum();
        assert!((busy - total_compute).abs() < 1e-9);
        assert!((m.energy_j() - total_energy).abs() < 1e-9);
        assert!((m.ms_total() - ms_sum).abs() < 1e-9);
        assert_eq!(m.total_tasks(), tasks.len() as u64);
        // Busy-until never precedes the clock by construction.
        assert!(state.busy_until.iter().all(|&b| b >= 0.0));
    }
}

/// Invariant: matching score is -1 past the safety time for detection and
/// bounded on both sides everywhere.
#[test]
fn matching_score_properties() {
    let mut rng = Rng::new(7);
    for _ in 0..2000 {
        let st = rng.range_f64(1e-3, 3.0);
        let resp = rng.range_f64(0.0, 6.0);
        for cat in [TaskCategory::Detection, TaskCategory::Tracking] {
            let ms = matching_score(cat, resp, st);
            assert!((-1.0..=1.0).contains(&ms));
            if resp > st {
                assert_eq!(ms, -1.0, "late tasks always score -1");
            } else {
                assert!(ms > -1.0 || cat == TaskCategory::Tracking);
                assert!(ms >= -1.0);
            }
        }
        // Detection MS grows with response inside the accepted region
        // (the Fig. 7 energy-saving ramp).
        let r1 = rng.range_f64(0.0, st * 0.5);
        let r2 = rng.range_f64(st * 0.5, st);
        let m1 = matching_score(TaskCategory::Detection, r1, st);
        let m2 = matching_score(TaskCategory::Detection, r2, st);
        assert!(m2 >= m1, "ramp must be nondecreasing: {m1} vs {m2}");
    }
}

/// Invariant: RSS safety times shrink with faster areas and grow with
/// camera sensing distance.
#[test]
fn rss_safety_time_monotonicity() {
    for scenario in [Scenario::GoStraight, Scenario::Turn] {
        for g in ALL_GROUPS {
            let ub = safety_time(Area::Urban, scenario, g);
            let uhw = safety_time(Area::UndividedHighway, scenario, g);
            let hw = safety_time(Area::Highway, scenario, g);
            assert!(ub > 0.0 && uhw > 0.0 && hw > 0.0);
            assert!(ub >= uhw && uhw >= hw, "{scenario:?} {g:?}: {ub} {uhw} {hw}");
        }
        // Longer-range camera => more headroom => larger safety time.
        let fc = safety_time(Area::Urban, scenario, CameraGroup::Fc);
        let side = safety_time(Area::Urban, scenario, CameraGroup::Flsc);
        assert!(fc >= side, "{scenario:?}: FC {fc} vs side {side}");
    }
}

/// Invariant: generated routes partition their duration, respect area
/// rules, and task queues are release-sorted with positive safety times.
#[test]
fn route_and_queue_invariants_random() {
    let mut rng = Rng::new(0xabcd);
    for _ in 0..20 {
        let area = ALL_AREAS[rng.below(3)];
        let dist = rng.range_f64(50.0, 400.0);
        let route = Route::generate(RouteParams::for_area(area, dist), &mut rng);
        // Segments tile [0, duration) without overlap.
        let mut t = 0.0;
        for s in &route.segments {
            assert!((s.start_s - t).abs() < 1e-9, "gap at {t}");
            assert!(s.duration_s > 0.0);
            t = s.end_s();
        }
        assert!((t - route.duration_s).abs() < 1e-6);
        if area == Area::Highway {
            assert!(route.segments.iter().all(|s| s.scenario != Scenario::Reverse));
        }
        let q = taskgen::generate(&route);
        assert!(q.tasks.windows(2).all(|w| w[0].release_s <= w[1].release_s));
        assert!(q.tasks.iter().all(|t| t.safety_time_s > 0.0));
        assert!(q.tasks.iter().all(|t| t.release_s < route.duration_s));
    }
}

/// Invariant: any feasible allocation found by the exhaustive search
/// actually covers the requirements, never over-uses the platform, and
/// reports utilization in (0, 1].
#[test]
fn allocation_search_soundness_random() {
    let mut rng = Rng::new(0x5eed);
    for _ in 0..40 {
        let counts = (rng.below(6), rng.below(6), rng.below(6));
        let area = ALL_AREAS[rng.below(3)];
        let scenario = [Scenario::GoStraight, Scenario::Turn][rng.below(2)];
        let reqs = alloc::requirements(area, scenario);
        if let Some((a, u)) = alloc::best_allocation(counts, &reqs) {
            assert!(alloc::feasible(&a, &reqs));
            assert!(u > 0.0 && u <= 1.0 + 1e-9);
            // Per-kind usage within the platform's counts.
            let totals = [counts.0, counts.1, counts.2];
            for k in ALL_ACCELS {
                let used: usize = (0..3).map(|m| a[k.index()][m]).sum();
                assert!(used <= totals[k.index()]);
            }
            assert!(alloc::power_w_provisioned(&a, &reqs, counts) > 0.0);
        }
    }
}

/// Invariant: scheduler assignments are always in range, for random
/// platforms and random bursts, for every constructible scheduler.
#[test]
fn schedulers_in_range_on_random_platforms() {
    let reg = hmai::sched::Registry::new();
    let mut rng = Rng::new(0xdead);
    for trial in 0..15 {
        let platform = random_platform(&mut rng);
        let state = ShadowState::new(&platform, NormScales::unit());
        let burst: Vec<Task> = (0..rng.int_range(1, 40) as u32)
            .map(|i| {
                let mut t = random_task(&mut rng, i);
                t.release_s = 0.0;
                t
            })
            .collect();
        for name in ["minmin", "ata", "edp", "ga", "sa", "worst", "rr", "random"] {
            let mut s = reg.build_by_name(name, trial).unwrap();
            let a = s.schedule_batch(&burst, &state);
            assert_eq!(a.len(), burst.len(), "{name}");
            assert!(a.iter().all(|&i| i < platform.len()), "{name} out of range");
        }
    }
}

/// Invariant: ModelKind task features feed consistent Task-Info.
#[test]
fn task_info_consistency() {
    let mut rng = Rng::new(1);
    for i in 0..200 {
        let t = random_task(&mut rng, i);
        assert!(t.amount_gmacs() > 0.0);
        assert!(t.layer_num() > 0);
        assert!((t.deadline_s() - (t.release_s + t.safety_time_s)).abs() < 1e-12);
        match t.model {
            ModelKind::Goturn => assert!(t.model.is_tracker()),
            _ => assert!(!t.model.is_tracker()),
        }
    }
}
