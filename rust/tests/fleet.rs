//! Fleet sweep service integration suite: the merged report of a sharded,
//! checkpoint-resumable sweep must be fingerprint-identical to the
//! monolithic `sweep_streaming` run — for any shard count, and across a
//! kill/resume cycle — and the streaming tail percentiles must pin
//! against exact sort-based quantiles of the per-task records.

use std::path::{Path, PathBuf};

use hmai::config::ExperimentConfig;
use hmai::engine::Engine;
use hmai::fleet::{merge_checkpoints, run_shard, FleetPlan, ShardCheckpoint, WorkOptions};
use hmai::metrics::summary::SweepSummary;
use hmai::safety::braking::{braking_distance_m, BrakingBreakdown};
use hmai::sched::Registry;
use hmai::sim::SimOptions;

/// 2 schedulers × 2 distances × 2 replicate seeds = 8 trials.
fn fleet_plan() -> FleetPlan {
    let mut cfg = ExperimentConfig::default();
    cfg.scheduler = "rr,minmin".into();
    cfg.env.distances_m = vec![40.0, 60.0];
    cfg.env.seed = 9;
    cfg.replicates = 2;
    FleetPlan::from_config(&cfg, 1).unwrap()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hmai_fleet_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn monolithic(plan: &FleetPlan, reg: &Registry) -> SweepSummary {
    let ep = plan.experiment_plan().unwrap();
    Engine::new(reg).events(plan.events).sweep_streaming(&ep).unwrap()
}

fn run_all_shards(
    plan: &FleetPlan,
    reg: &Registry,
    dir: &Path,
    opts: WorkOptions,
) -> Vec<ShardCheckpoint> {
    let resolved = plan.resolve().unwrap();
    (0..resolved.shards.len())
        .map(|s| {
            let path = dir.join(format!("shard_{s}.json"));
            run_shard(reg, plan, &resolved, s, &path, opts).unwrap()
        })
        .collect()
}

#[test]
fn any_partition_matches_monolithic_fingerprint() {
    let reg = Registry::new();
    let mut plan = fleet_plan();
    let whole = monolithic(&plan, &reg);
    for shards in [1usize, 2, 4] {
        plan.shards = shards;
        let resolved = plan.resolve().unwrap();
        let sub = temp_dir(&format!("partition_{shards}"));
        let parts = run_all_shards(
            &plan,
            &reg,
            &sub,
            WorkOptions { jobs: 1, checkpoint_every: 3, max_trials: None },
        );
        let merged = merge_checkpoints(&resolved, &parts).unwrap();
        assert_eq!(
            merged.fingerprint(),
            whole.fingerprint(),
            "{shards}-shard merge drifted from the monolithic sweep"
        );
        assert_eq!(merged.total_runs(), whole.total_runs());
        std::fs::remove_dir_all(&sub).ok();
    }
}

#[test]
fn kill_mid_shard_then_resume_is_invisible() {
    let reg = Registry::new();
    let mut plan = fleet_plan();
    plan.shards = 2;
    let resolved = plan.resolve().unwrap();
    let whole = monolithic(&plan, &reg);
    let dir = temp_dir("resume");
    let p0 = dir.join("shard_0.json");
    let p1 = dir.join("shard_1.json");

    // "Kill" shard 0 after two trials: a valid mid-shard checkpoint.
    let stop = WorkOptions { jobs: 1, checkpoint_every: 1, max_trials: Some(2) };
    let partial = run_shard(&reg, &plan, &resolved, 0, &p0, stop).unwrap();
    assert!(!partial.complete(), "max_trials must stop mid-shard");
    assert_eq!(partial.next_trial, resolved.shards[0].lo + 2);

    // Resume from the on-disk checkpoint and finish both shards.
    let go = WorkOptions { jobs: 1, checkpoint_every: 3, max_trials: None };
    let s0 = run_shard(&reg, &plan, &resolved, 0, &p0, go).unwrap();
    let s1 = run_shard(&reg, &plan, &resolved, 1, &p1, go).unwrap();
    assert!(s0.complete() && s1.complete());

    let merged = merge_checkpoints(&resolved, &[s0, s1]).unwrap();
    assert_eq!(
        merged.fingerprint(),
        whole.fingerprint(),
        "kill/resume cycle changed the merged result"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_checkpoint_is_quarantined_and_the_shard_recovers() {
    let reg = Registry::new();
    let mut plan = fleet_plan();
    plan.shards = 2;
    let resolved = plan.resolve().unwrap();
    let whole = monolithic(&plan, &reg);
    let dir = temp_dir("quarantine");
    let p0 = dir.join("shard_0.json");
    let p1 = dir.join("shard_1.json");

    // Stop shard 0 mid-range, then corrupt its checkpoint the way a crash
    // outside the atomic write path would: truncate the file.
    let stop = WorkOptions { jobs: 1, checkpoint_every: 1, max_trials: Some(2) };
    run_shard(&reg, &plan, &resolved, 0, &p0, stop).unwrap();
    let text = std::fs::read_to_string(&p0).unwrap();
    std::fs::write(&p0, &text[..100]).unwrap();

    // The resume must quarantine the file, restart the shard fresh, and
    // still produce the complete, correct shard summary.
    let go = WorkOptions { jobs: 1, checkpoint_every: 3, max_trials: None };
    let s0 = run_shard(&reg, &plan, &resolved, 0, &p0, go).unwrap();
    assert!(s0.complete(), "shard must recover from a corrupt checkpoint");
    let quarantined = dir.join("shard_0.json.corrupt");
    assert!(quarantined.exists(), "corrupt file kept as evidence");
    assert_eq!(std::fs::read_to_string(&quarantined).unwrap(), &text[..100]);

    // A second corruption quarantines under a numbered name.
    let good = std::fs::read_to_string(&p0).unwrap();
    std::fs::write(&p0, &good[..80]).unwrap();
    let s0_again = run_shard(&reg, &plan, &resolved, 0, &p0, go).unwrap();
    assert!(s0_again.complete());
    assert!(dir.join("shard_0.json.corrupt.1").exists());

    // The merged fleet result is unaffected by the whole ordeal.
    let s1 = run_shard(&reg, &plan, &resolved, 1, &p1, go).unwrap();
    let merged = merge_checkpoints(&resolved, &[s0_again, s1]).unwrap();
    assert_eq!(
        merged.fingerprint(),
        whole.fingerprint(),
        "quarantine/restart changed the merged result"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_file_roundtrip_is_byte_exact() {
    let reg = Registry::new();
    let mut plan = fleet_plan();
    plan.shards = 2;
    let resolved = plan.resolve().unwrap();
    let dir = temp_dir("roundtrip");
    let path = dir.join("shard_0.json");
    let opts = WorkOptions { jobs: 1, checkpoint_every: 2, max_trials: None };
    let live = run_shard(&reg, &plan, &resolved, 0, &path, opts).unwrap();

    // The on-disk state reloads to the same fingerprint, and re-serializing
    // the loaded state reproduces the file byte-for-byte (f64 sums travel
    // as bit hex, so nothing is lost to decimal formatting).
    let back = ShardCheckpoint::load(&path).unwrap();
    assert_eq!(back.spec, live.spec);
    assert_eq!(back.next_trial, live.next_trial);
    assert_eq!(back.summary.fingerprint(), live.summary.fingerprint());
    assert_eq!(back.to_json().to_pretty(), live.to_json().to_pretty());

    // A second load of a re-save is equally stable.
    let resaved = dir.join("resaved.json");
    back.save(&resaved).unwrap();
    assert_eq!(
        std::fs::read_to_string(&path).unwrap(),
        std::fs::read_to_string(&resaved).unwrap()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streaming_tails_pin_against_exact_quantiles() {
    let reg = Registry::new();
    let plan = fleet_plan();
    let trials = plan.experiment_plan().unwrap().trials().unwrap();
    let engine = Engine::new(&reg).sim_options(SimOptions { record_tasks: true });
    for trial in trials.iter().take(4) {
        let r = engine.run_trial(trial).unwrap();
        assert!(!r.records.is_empty());
        let v = trial.scenario.area.max_velocity_ms();
        let resp: Vec<f64> = r.records.iter().map(|t| t.response_s).collect();
        let brk: Vec<f64> = r
            .records
            .iter()
            .map(|t| braking_distance_m(v, &BrakingBreakdown::new(t.wait_s, 0.0, t.compute_s)))
            .collect();
        assert_eq!(r.summary.response_hist.count(), resp.len() as u64);
        assert_eq!(r.summary.braking_hist.count(), brk.len() as u64);
        for (vals, hist, what) in [
            (&resp, &r.summary.response_hist, "response"),
            (&brk, &r.summary.braking_hist, "braking"),
        ] {
            let mut sorted = vals.to_vec();
            sorted.sort_by(f64::total_cmp);
            let n = sorted.len();
            for q in [0.50, 0.90, 0.99, 0.999] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = sorted[rank - 1];
                let got = hist.quantile(q);
                if !exact.is_finite() {
                    assert!(!got.is_finite(), "trial {} {what} q{q}", trial.id);
                    continue;
                }
                let rel = (got - exact).abs() / exact.abs().max(1e-12);
                assert!(
                    rel <= 0.07,
                    "trial {} {what} q{q}: hist {got} vs exact {exact} (rel {rel:.4})",
                    trial.id
                );
            }
        }
    }
}
