//! The determinism and registry contracts of the typed sweep API:
//! parallel `Engine` output is bit-identical to sequential execution, and
//! the scheduler registry round-trips every canonical name and alias.

use hmai::engine::Engine;
use hmai::env::taskgen::DeadlineMode;
use hmai::env::Area;
use hmai::metrics::summary::SweepSummary;
use hmai::plan::ExperimentPlan;
use hmai::sched::{Registry, SchedulerSpec, SCHEDULERS};
use hmai::sim::SimOptions;

/// A sweep touching every axis: 2 areas × 2 distances × 2 deadline
/// regimes × 2 platforms × 4 schedulers (incl. every seeded one) = 64
/// trials — small routes so the whole matrix stays fast.
fn wide_plan() -> ExperimentPlan {
    ExperimentPlan::new()
        .areas([Area::Urban, Area::Highway])
        .distances([40.0, 60.0])
        .deadlines([DeadlineMode::Rss, DeadlineMode::FrameBudget])
        .platforms(["hmai", "2,2,2"])
        .schedulers([
            SchedulerSpec::MinMin,
            SchedulerSpec::Ga,
            SchedulerSpec::Sa,
            SchedulerSpec::Random,
        ])
        .seed(42)
}

#[test]
fn parallel_engine_is_bit_identical_to_sequential() {
    let reg = Registry::new();
    let plan = wide_plan();
    let (seq, seq_sweep) = Engine::new(&reg).jobs(1).sweep(&plan).unwrap();
    for jobs in [2, 4] {
        let (par, par_sweep) = Engine::new(&reg).jobs(jobs).sweep(&plan).unwrap();
        assert_eq!(seq.len(), par.len(), "jobs={jobs}");
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.trial.id, b.trial.id);
            let (x, y) = (&a.summary, &b.summary);
            assert_eq!(x.scheduler, y.scheduler);
            assert_eq!(x.platform, y.platform);
            assert_eq!(x.tasks, y.tasks, "trial {}", a.trial.id);
            assert_eq!(x.tasks_met, y.tasks_met, "trial {}", a.trial.id);
            // Bit-exact floating-point equality, not epsilon comparison.
            for (fa, fb, field) in [
                (x.energy_j, y.energy_j, "energy_j"),
                (x.makespan_s, y.makespan_s, "makespan_s"),
                (x.wait_s, y.wait_s, "wait_s"),
                (x.compute_s, y.compute_s, "compute_s"),
                (x.r_balance, y.r_balance, "r_balance"),
                (x.ms_total, y.ms_total, "ms_total"),
                (x.gvalue, y.gvalue, "gvalue"),
                (x.mean_response_s, y.mean_response_s, "mean_response_s"),
                (x.max_response_s, y.max_response_s, "max_response_s"),
            ] {
                assert_eq!(
                    fa.to_bits(),
                    fb.to_bits(),
                    "trial {} field {field}: {fa} vs {fb} (jobs={jobs})",
                    a.trial.id
                );
            }
        }
        assert_eq!(
            seq_sweep.fingerprint(),
            par_sweep.fingerprint(),
            "sweep fingerprint drifted at jobs={jobs}"
        );
    }
}

#[test]
fn engine_rerun_is_bit_identical() {
    // Same plan, same registry, run twice: identical fingerprints (no
    // hidden global state in schedulers or queue generation).
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .distances([50.0])
        .schedulers([SchedulerSpec::Sa, SchedulerSpec::Random])
        .seed(9);
    let (_, a) = Engine::new(&reg).jobs(2).sweep(&plan).unwrap();
    let (_, b) = Engine::new(&reg).jobs(2).sweep(&plan).unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint());
}

#[test]
fn record_tasks_identical_across_jobs() {
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .distances([40.0, 50.0])
        .schedulers([SchedulerSpec::RoundRobin, SchedulerSpec::MinMin])
        .seed(4);
    let run = |jobs| {
        Engine::new(&reg)
            .jobs(jobs)
            .sim_options(SimOptions { record_tasks: true })
            .run(&plan)
            .unwrap()
    };
    let (seq, par) = (run(1), run(3));
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.task_id, rb.task_id);
            assert_eq!(ra.accel, rb.accel, "trial {} task {}", a.trial.id, ra.task_id);
            assert_eq!(ra.finish_s.to_bits(), rb.finish_s.to_bits());
        }
    }
}

#[test]
fn registry_round_trips_every_name_and_alias() {
    let reg = Registry::new();
    for info in SCHEDULERS {
        for name in std::iter::once(&info.canonical).chain(info.aliases) {
            let spec = SchedulerSpec::parse(name)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(spec.canonical(), info.canonical, "{name}");
            if info.canonical == "flexai" {
                // Registered only via harness::registry; the base registry
                // must fail with a clear pointer, not a panic.
                let err = reg.build(&spec, 1).unwrap_err();
                assert!(format!("{err:#}").contains("PJRT"), "{err:#}");
            } else {
                let s = reg.build(&spec, 1).unwrap_or_else(|e| panic!("{name}: {e:#}"));
                assert_eq!(s.name(), info.display, "{name}");
            }
        }
    }
    // Unknown names error (never panic) and name the known set.
    let err = reg.build_by_name("definitely-not-a-scheduler", 0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown scheduler"), "{msg}");
    assert!(msg.contains("minmin"), "{msg}");
}

#[test]
fn sweep_summary_groups_follow_trial_order() {
    let reg = Registry::new();
    let plan = ExperimentPlan::new()
        .distances([40.0, 60.0])
        .schedulers([SchedulerSpec::MinMin, SchedulerSpec::Worst])
        .seed(2);
    let (results, sweep) = Engine::new(&reg).jobs(2).sweep(&plan).unwrap();
    assert_eq!(results.len(), 4);
    assert_eq!(sweep.groups.len(), 2, "one group per scheduler");
    assert_eq!(sweep.groups[0].key.scheduler, "Min-Min");
    assert_eq!(sweep.groups[1].key.scheduler, "WorstCase");
    assert_eq!(sweep.total_runs(), 4);
    // Rebuilding the summary from the ordered results is idempotent.
    let again = SweepSummary::from_trial_results(&results);
    assert_eq!(again.fingerprint(), sweep.fingerprint());
}
