"""AOT pipeline: every entry lowers to parseable HLO text and meta.json is
consistent with model dims.  Also round-trips qnet_infer through jax's own
CPU backend from the lowered module to pin down numerics before rust runs
the same HLO through PJRT."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import ref_qnet_fwd

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def entries():
    return aot.lower_entries()


def test_all_entries_lower(entries):
    assert set(entries) == {
        "qnet_infer", "qnet_infer_batch", "qnet_train", "qnet_init",
    }


@pytest.mark.parametrize(
    "name", ["qnet_infer", "qnet_infer_batch", "qnet_train", "qnet_init"]
)
def test_hlo_text_structure(entries, name):
    text = aot.to_hlo_text(entries[name])
    assert "ENTRY" in text and "ROOT" in text
    # Pallas (interpret) must have lowered to plain HLO: no custom-calls that
    # the rust CPU PJRT client cannot execute.
    assert "custom-call" not in text, f"{name} contains a custom-call"


def _entry_param_count(text: str) -> int:
    """Count parameters of the ENTRY computation only (subcomputations from
    the pallas-lowered loops have their own parameter() instructions)."""
    n, in_entry = 0, False
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry:
            if line.startswith("}"):
                break
            if "parameter(" in line:
                n += 1
    return n


def test_infer_hlo_param_count(entries):
    # 6 params + 1 state input
    assert _entry_param_count(aot.to_hlo_text(entries["qnet_infer"])) == 7


def test_train_hlo_param_count(entries):
    # 6 eval + 6 target params + 5 batch tensors
    assert _entry_param_count(aot.to_hlo_text(entries["qnet_train"])) == 17


def test_meta_roundtrip(tmp_path):
    aot.write_meta(str(tmp_path))
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["in_dim"] == model.IN_DIM == (
        meta["task_feats"] + meta["slot_feats"] * meta["n_slots"]
    )
    assert meta["out_dim"] == model.OUT_DIM
    assert meta["param_shapes"] == [list(s) for s in model.PARAM_SHAPES]
    assert meta["lr"] == model.LR and meta["gamma"] == model.GAMMA


def test_lowered_infer_numerics(entries):
    """Compile the lowered infer module in-process and diff against ref."""
    exe = entries["qnet_infer"].compile()
    params = model.init_params(jnp.int32(3))
    x = jax.random.normal(jax.random.PRNGKey(11), (1, model.IN_DIM))
    (got,) = exe(*params, x)
    np.testing.assert_allclose(got, ref_qnet_fwd(params, x), rtol=1e-4, atol=1e-4)


def test_lowered_train_numerics(entries):
    exe = entries["qnet_train"].compile()
    p = model.init_params(jnp.int32(4))
    t = model.init_params(jnp.int32(5))
    B = model.TRAIN_BATCH
    ks = jax.random.split(jax.random.PRNGKey(12), 4)
    s = jax.random.normal(ks[0], (B, model.IN_DIM))
    a = jax.random.randint(ks[1], (B,), 0, model.OUT_DIM)
    r = jax.random.normal(ks[2], (B,))
    s2 = jax.random.normal(ks[3], (B, model.IN_DIM))
    done = jnp.zeros(B)
    out = exe(*p, *t, s, a, r, s2, done)
    assert len(out) == 7  # 6 new params + loss
    new_p, loss = out[:6], out[6]
    want_p, want_loss = model.train_step(p, t, s, a, r, s2, done)
    np.testing.assert_allclose(loss, want_loss, rtol=1e-4, atol=1e-5)
    for g, w in zip(new_p, want_p):
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-4)
