"""L1 correctness: Pallas fused_linear vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: values and
gradients must match ref.py across a hypothesis sweep of shapes, with and
without the fused ReLU, including shapes that do not divide the MXU block
sizes (exercising the pad/slice path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_linear import (
    BLOCK_K,
    BLOCK_M,
    BLOCK_N,
    fused_linear,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import ref_fused_linear
from compile.model import IN_DIM

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def _mk(m, k, n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return _rand(ks[0], m, k), _rand(ks[1], k, n), _rand(ks[2], n)


# ---------------------------------------------------------------------------
# Fixed-shape smoke tests (the exact layer shapes the Q-network uses).
# ---------------------------------------------------------------------------

QNET_SHAPES = [(1, IN_DIM, 256), (1, 256, 64), (1, 64, 16),
               (64, IN_DIM, 256), (64, 256, 64), (64, 64, 16),
               (30, IN_DIM, 256)]


@pytest.mark.parametrize("m,k,n", QNET_SHAPES)
@pytest.mark.parametrize("relu", [False, True])
def test_qnet_layer_shapes(m, k, n, relu):
    x, w, b = _mk(m, k, n, seed=m * 7 + k + n + int(relu))
    got = fused_linear(x, w, b, relu)
    want = ref_fused_linear(x, w, b, relu)
    # atol covers fp32 accumulation-order differences near ReLU zeros.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", QNET_SHAPES)
def test_qnet_layer_grads(m, k, n):
    x, w, b = _mk(m, k, n, seed=m + k + n)

    def f_pallas(x, w, b):
        return jnp.sum(jnp.sin(fused_linear(x, w, b, True)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref_fused_linear(x, w, b, True)))

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweep: arbitrary shapes, both activations.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 160),
    n=st.integers(1, 140),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, relu, seed):
    x, w, b = _mk(m, k, n, seed)
    got = fused_linear(x, w, b, relu)
    assert got.shape == (m, n)
    want = ref_fused_linear(x, w, b, relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 100),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_grad_matches_ref(m, k, n, seed):
    x, w, b = _mk(m, k, n, seed)

    def f_pallas(x, w, b):
        return jnp.sum(fused_linear(x, w, b, True) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref_fused_linear(x, w, b, True) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Edge cases and structural properties.
# ---------------------------------------------------------------------------


def test_relu_clamps_negative():
    x = -jnp.ones((4, 8))
    w = jnp.eye(8)
    b = jnp.zeros(8)
    y = fused_linear(x, w, b, True)
    assert float(jnp.max(y)) == 0.0


def test_bias_broadcast():
    x = jnp.zeros((3, 5))
    w = jnp.zeros((5, 7))
    b = jnp.arange(7, dtype=jnp.float32)
    y = fused_linear(x, w, b, False)
    np.testing.assert_allclose(y, jnp.broadcast_to(b, (3, 7)))


def test_blocks_larger_than_problem():
    # Whole problem fits one tile: grid collapses to (1,1,1).
    x, w, b = _mk(2, 3, 4, seed=0)
    np.testing.assert_allclose(
        fused_linear(x, w, b, False), ref_fused_linear(x, w, b, False),
        rtol=1e-5, atol=1e-6,
    )


def test_exact_block_multiples():
    m, k, n = BLOCK_M, BLOCK_K, BLOCK_N
    x, w, b = _mk(m, k, n, seed=1)
    np.testing.assert_allclose(
        fused_linear(x, w, b, True), ref_fused_linear(x, w, b, True),
        rtol=1e-4, atol=1e-4,
    )


def test_jit_compatible():
    x, w, b = _mk(8, 16, 8, seed=2)
    f = jax.jit(lambda x, w, b: fused_linear(x, w, b, True))
    np.testing.assert_allclose(
        f(x, w, b), ref_fused_linear(x, w, b, True), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# §Perf analysis helpers (DESIGN.md §Hardware-Adaptation).
# ---------------------------------------------------------------------------


def test_vmem_footprint_fits_budget():
    # Every Q-network layer's tile set must fit a 16 MiB VMEM.
    for m, k, n in QNET_SHAPES:
        assert vmem_footprint_bytes(m, k, n) < 16 * 1024 * 1024


def test_mxu_utilization_bounds():
    for m, k, n in QNET_SHAPES:
        u = mxu_utilization_estimate(m, k, n)
        assert 0.0 < u <= 1.0
    # Perfectly-tiled problem wastes nothing.
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
