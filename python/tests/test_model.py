"""L2 correctness: Q-network forward + DQN train step vs pure-jnp oracle,
plus learning-dynamics sanity (loss decreases, params move, target net
frozen)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import ref_qnet_fwd, ref_td_loss, ref_train_step

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model.init_params(jnp.int32(0))


@pytest.fixture(scope="module")
def targ_params():
    return model.init_params(jnp.int32(1))


def _batch(seed, B=model.TRAIN_BATCH):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    s = jax.random.normal(ks[0], (B, model.IN_DIM), jnp.float32)
    a = jax.random.randint(ks[1], (B,), 0, model.OUT_DIM)
    r = jax.random.normal(ks[2], (B,), jnp.float32)
    s2 = jax.random.normal(ks[3], (B, model.IN_DIM), jnp.float32)
    done = (jax.random.uniform(ks[4], (B,)) < 0.1).astype(jnp.float32)
    return s, a, r, s2, done


# ---------------------------------------------------------------------------
# Structure
# ---------------------------------------------------------------------------


def test_param_shapes(params):
    assert [p.shape for p in params] == [tuple(s) for s in model.PARAM_SHAPES]


def test_init_deterministic():
    a = model.init_params(jnp.int32(42))
    b = model.init_params(jnp.int32(42))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_init_seed_sensitivity():
    a = model.init_params(jnp.int32(0))
    b = model.init_params(jnp.int32(1))
    assert float(jnp.max(jnp.abs(a[0] - b[0]))) > 0.0


def test_fwd_shape(params):
    x = jnp.zeros((5, model.IN_DIM))
    assert model.qnet_fwd(params, x).shape == (5, model.OUT_DIM)


# ---------------------------------------------------------------------------
# Numerics vs oracle
# ---------------------------------------------------------------------------


def test_fwd_matches_ref(params):
    x = jax.random.normal(jax.random.PRNGKey(7), (9, model.IN_DIM))
    np.testing.assert_allclose(
        model.qnet_fwd(params, x), ref_qnet_fwd(params, x),
        rtol=1e-4, atol=1e-4,
    )


def test_td_loss_matches_ref(params, targ_params):
    s, a, r, s2, done = _batch(3)
    got = model.td_loss(params, targ_params, s, a, r, s2, done)
    want = ref_td_loss(params, targ_params, s, a, r, s2, done, model.GAMMA)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_train_step_matches_ref(params, targ_params):
    s, a, r, s2, done = _batch(4)
    new_p, loss = model.train_step(params, targ_params, s, a, r, s2, done)
    ref_p, ref_loss = ref_train_step(
        params, targ_params, s, a, r, s2, done, model.GAMMA, model.LR
    )
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-4, atol=1e-5)
    for g, w in zip(new_p, ref_p):
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Learning dynamics
# ---------------------------------------------------------------------------


def test_train_step_moves_params(params, targ_params):
    s, a, r, s2, done = _batch(5)
    new_p, _ = model.train_step(params, targ_params, s, a, r, s2, done)
    assert any(float(jnp.max(jnp.abs(n - o))) > 0 for n, o in zip(new_p, params))


def test_repeated_steps_reduce_loss(params, targ_params):
    """On a fixed batch (fixed TD target), SGD must reduce the loss."""
    s, a, r, s2, done = _batch(6)
    step = jax.jit(model.train_step)
    p = params
    first = None
    for _ in range(20):
        p, loss = step(p, targ_params, s, a, r, s2, done)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.9


def test_done_masks_bootstrap(params, targ_params):
    """done=1 must remove the gamma * max Q(s') term from the target."""
    s, a, r, s2, _ = _batch(8, B=4)
    done1 = jnp.ones(4, jnp.float32)
    loss_done = model.td_loss(params, targ_params, s, a, r, s2, done1)
    q = model.qnet_fwd(params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    np.testing.assert_allclose(
        loss_done, jnp.mean((r - q_sa) ** 2), rtol=1e-4, atol=1e-5
    )
