"""Pure-jnp oracles for the Pallas kernels and the L2 model.

Everything in this file is deliberately written with plain jax.numpy ops —
no Pallas, no custom_vjp — so pytest can diff the optimized path against an
independent implementation (values *and* gradients).
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp


def ref_fused_linear(x, w, b, relu: bool = False):
    """Oracle for kernels.fused_linear."""
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


def ref_qnet_fwd(params: List[jax.Array], x: jax.Array) -> jax.Array:
    """Oracle for model.qnet_fwd.  params = [w1, b1, w2, b2, w3, b3]."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = jnp.maximum(x @ w1 + b1[None, :], 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2[None, :], 0.0)
    return h2 @ w3 + b3[None, :]


def ref_td_loss(
    params: List[jax.Array],
    targ_params: List[jax.Array],
    s: jax.Array,
    a: jax.Array,
    r: jax.Array,
    s2: jax.Array,
    done: jax.Array,
    gamma: float,
) -> jax.Array:
    """Oracle for the DQN TD loss (paper §7.1: L = (y - max Q)^2 with
    y = r + gamma * max_a' Q_targ(s'))."""
    q = ref_qnet_fwd(params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    q_next = ref_qnet_fwd(targ_params, s2)
    y = r + gamma * (1.0 - done) * jnp.max(q_next, axis=1)
    y = jax.lax.stop_gradient(y)
    return jnp.mean((y - q_sa) ** 2)


def ref_sgd_step(
    params: List[jax.Array], grads: List[jax.Array], lr: float
) -> List[jax.Array]:
    return [p - lr * g for p, g in zip(params, grads)]


def ref_train_step(
    params: List[jax.Array],
    targ_params: List[jax.Array],
    s, a, r, s2, done,
    gamma: float,
    lr: float,
) -> Tuple[List[jax.Array], jax.Array]:
    """Oracle for model.train_step: one SGD step on the TD loss."""
    loss, grads = jax.value_and_grad(ref_td_loss)(
        params, targ_params, s, a, r, s2, done, gamma
    )
    return ref_sgd_step(params, grads, lr), loss
