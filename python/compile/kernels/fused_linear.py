"""L1 — Pallas fused linear kernels for the FlexAI Q-network.

The FLOP-dominant op of both the scheduling (inference) path and the DQN
train step is the dense layer ``y = relu(x @ w + b)``.  We implement it as a
tiled Pallas kernel plus the two backward kernels (dX, dW) and wire them
into JAX autodiff with ``jax.custom_vjp`` so the L2 model (model.py) can be
differentiated end-to-end while the hot matmuls stay in Pallas.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): tiles default to
(128, 128) output blocks with a 128-deep reduction so each grid step is one
MXU-shaped systolic pass; BlockSpec index maps express the HBM->VMEM
schedule.  All ``pallas_call``s use ``interpret=True`` — the CPU PJRT
backend cannot execute Mosaic custom-calls, and interpret mode lowers to
plain HLO so the AOT artifacts run anywhere (aot_recipe / load_hlo notes).

Shapes that do not divide the block sizes are zero-padded by the wrappers
and sliced back afterwards; zero padding is exact for matmul + bias + relu.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes, MXU-oriented (128x128 systolic array).
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols)."""
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _pad1(x: jax.Array, n: int) -> jax.Array:
    if x.shape[0] == n:
        return x
    return jnp.pad(x, (0, n - x.shape[0]))


# ---------------------------------------------------------------------------
# Forward: y = (x @ w + b), optionally ReLU-fused.
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps: int, relu: bool):
    """One (bm, bn) output tile; grid axis 2 walks the K reduction.

    The output ref doubles as the accumulator: the same (i, j) block is
    revisited for every k step (see the index maps in ``_fused_linear_raw``),
    so it lives in VMEM across the reduction.  Bias + activation are applied
    exactly once, on the final k step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _finish():
        y = o_ref[...] + b_ref[...][None, :]
        o_ref[...] = jnp.maximum(y, 0.0) if relu else y


def _fused_linear_raw(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    relu: bool,
    bm: int = BLOCK_M,
    bn: int = BLOCK_N,
    bk: int = BLOCK_K,
) -> jax.Array:
    """Tiled fused linear over padded operands."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    # Shrink blocks to the (padded) problem so tiny layers stay single-tile.
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp, np_, kp = bm * _ceil_div(m, bm), bn * _ceil_div(n, bn), bk * _ceil_div(k, bk)
    xp, wp, bp = _pad2(x, mp, kp), _pad2(w, kp, np_), _pad1(b, np_)
    k_steps = kp // bk

    out = pl.pallas_call(
        functools.partial(_fwd_kernel, k_steps=k_steps, relu=relu),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Backward kernels.
#   dX = g_eff @ w.T        (g_eff already ReLU-masked by the vjp wrapper)
#   dW = x.T @ g_eff
# ---------------------------------------------------------------------------


def _dx_kernel(g_ref, w_ref, o_ref, *, k_steps: int):
    """dX tile: accumulate g(bm, bn) @ w(bk, bn).T over the N reduction."""
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        g_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )


def _matmul_nt_raw(g: jax.Array, w: jax.Array, bm: int, bn: int, bk: int) -> jax.Array:
    """g[M,N] @ w[K,N].T -> [M,K]; reduction runs over N."""
    m, n = g.shape
    k, n2 = w.shape
    assert n == n2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp, np_, kp = bm * _ceil_div(m, bm), bn * _ceil_div(n, bn), bk * _ceil_div(k, bk)
    gp, wp = _pad2(g, mp, np_), _pad2(w, kp, np_)
    n_steps = np_ // bn

    out = pl.pallas_call(
        functools.partial(_dx_kernel, k_steps=n_steps),
        grid=(mp // bm, kp // bk, n_steps),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (j, s)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), jnp.float32),
        interpret=True,
    )(gp, wp)
    return out[:m, :k]


def _dw_kernel(x_ref, g_ref, o_ref, *, m_steps: int):
    """dW tile: accumulate x(bm, bk).T @ g(bm, bn) over the batch reduction."""
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].T, g_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_tn_raw(x: jax.Array, g: jax.Array, bm: int, bn: int, bk: int) -> jax.Array:
    """x[M,K].T @ g[M,N] -> [K,N]; reduction runs over M (the batch)."""
    m, k = x.shape
    m2, n = g.shape
    assert m == m2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp, np_, kp = bm * _ceil_div(m, bm), bn * _ceil_div(n, bn), bk * _ceil_div(k, bk)
    xp, gp = _pad2(x, mp, kp), _pad2(g, mp, np_)
    m_steps = mp // bm

    out = pl.pallas_call(
        functools.partial(_dw_kernel, m_steps=m_steps),
        grid=(kp // bk, np_ // bn, m_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (s, i)),
            pl.BlockSpec((bm, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kp, np_), jnp.float32),
        interpret=True,
    )(xp, gp)
    return out[:k, :n]


# ---------------------------------------------------------------------------
# Autodiff wiring.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool = False):
    """``relu(x @ w + b)`` (or affine if ``relu=False``) as a Pallas kernel.

    Differentiable via custom_vjp: the backward pass reuses the Pallas
    matmul kernels (dX = g@w.T, dW = x.T@g) with the ReLU mask recovered
    from the forward output (y > 0 <=> pre-activation > 0 for ReLU).
    """
    return _fused_linear_raw(x, w, b, relu)


def _fused_linear_fwd(x, w, b, relu):
    y = _fused_linear_raw(x, w, b, relu)
    return y, (x, w, y)


def _fused_linear_bwd(relu, res, g):
    x, w, y = res
    g_eff = jnp.where(y > 0.0, g, 0.0) if relu else g
    dx = _matmul_nt_raw(g_eff, w, BLOCK_M, BLOCK_N, BLOCK_K)
    dw = _matmul_tn_raw(x, g_eff, BLOCK_M, BLOCK_N, BLOCK_K)
    db = jnp.sum(g_eff, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def vmem_footprint_bytes(
    m: int, k: int, n: int, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K
) -> int:
    """Estimated VMEM bytes live per grid step of the forward kernel.

    x-tile + w-tile + bias-tile + output/accumulator tile, fp32.  Used by
    the §Perf analysis (DESIGN.md): the tile set must fit a ~16 MiB VMEM
    with room for double-buffering (×2 on the streamed operands).
    """
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    x_t, w_t, b_t, o_t = bm * bk, bk * bn, bn, bm * bn
    # Streamed operands are double-buffered; the accumulator is resident.
    return 4 * (2 * (x_t + w_t + b_t) + o_t)


def mxu_utilization_estimate(
    m: int, k: int, n: int, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K
) -> float:
    """Fraction of MXU lanes doing useful work (padding overhead model)."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp = bm * _ceil_div(m, bm)
    np_ = bn * _ceil_div(n, bn)
    kp = bk * _ceil_div(k, bk)
    useful = m * k * n
    issued = mp * kp * np_
    return useful / issued
