"""L2 — the FlexAI Q-network and DQN train step, in JAX, on Pallas kernels.

Topology follows the paper (§8.3): two fully-connected layers of 256 and 64
neurons with ReLU, then a linear head producing one Q value per accelerator
slot.  (The paper lists a softmax after the head; for Q-value regression a
softmax would destroy the TD target, so the head is linear — recorded as a
deviation in DESIGN.md.)

State layout (must match rust/src/sched/flexai/featurize.rs):
    [ task one-hot (3: YOLO | SSD | GOTURN),
      amount_norm, layer_num_norm, safety_time_norm,           # Task-Info
      per-slot x N_SLOTS:                                      # HW-Info
        [ valid, kind_so, kind_si, kind_mm,
          queue_time_norm, energy_share, rel_competitiveness, est_time_norm,
          comm_time_norm ] ]                                   # data locality
IN_DIM = 6 + 9 * N_SLOTS;  OUT_DIM = N_SLOTS.

`comm_time_norm` (v2 layout, SLOT_FEATS = 9) is the chiplet-interconnect
locality feature: predicted transfer time over the task's safety budget,
0 on monolithic platforms.  The rust featurizer gates it on the artifact's
`slot_feats`, so models compiled from the old 8-feature layout keep their
exact pre-interconnect inputs.

Everything here is build-time only: aot.py lowers `qnet_infer`,
`qnet_infer_batch`, `qnet_train` and `qnet_init` to HLO text which the rust
runtime executes through PJRT.  Python never runs on the request path.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear

# ---------------------------------------------------------------------------
# Dimensions — single source of truth, exported to rust via artifacts/meta.json.
# ---------------------------------------------------------------------------
N_SLOTS = 16              # max accelerator slots (HMAI uses 11: 4 SO + 4 SI + 3 MM)
TASK_FEATS = 6            # task one-hot(3) + amount + layer_num + safety_time
SLOT_FEATS = 9            # v2: + comm_time_norm (data locality)
IN_DIM = TASK_FEATS + SLOT_FEATS * N_SLOTS   # 150
H1 = 256                  # paper: first FC layer
H2 = 64                   # paper: second FC layer
OUT_DIM = N_SLOTS
TRAIN_BATCH = 64
INFER_BATCH = 30          # one camera burst (30 cameras firing together)
GAMMA = 0.95
LR = 0.01                 # paper: learning rate 0.01

PARAM_SHAPES: List[Tuple[int, ...]] = [
    (IN_DIM, H1), (H1,), (H1, H2), (H2,), (H2, OUT_DIM), (OUT_DIM,),
]
PARAM_NAMES = ["w1", "b1", "w2", "b2", "w3", "b3"]


def init_params(seed: jax.Array) -> List[jax.Array]:
    """He-initialised parameters from an int32 seed (AOT entry `qnet_init`)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    w1 = jax.random.normal(ks[0], (IN_DIM, H1), jnp.float32) * jnp.sqrt(2.0 / IN_DIM)
    w2 = jax.random.normal(ks[1], (H1, H2), jnp.float32) * jnp.sqrt(2.0 / H1)
    w3 = jax.random.normal(ks[2], (H2, OUT_DIM), jnp.float32) * jnp.sqrt(2.0 / H2)
    return [w1, jnp.zeros(H1), w2, jnp.zeros(H2), w3, jnp.zeros(OUT_DIM)]


def qnet_fwd(params: List[jax.Array], x: jax.Array) -> jax.Array:
    """Q(s, ·) for a batch of states — three fused Pallas layers."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = fused_linear(x, w1, b1, True)
    h2 = fused_linear(h1, w2, b2, True)
    return fused_linear(h2, w3, b3, False)


def td_loss(
    params: List[jax.Array],
    targ_params: List[jax.Array],
    s: jax.Array,
    a: jax.Array,
    r: jax.Array,
    s2: jax.Array,
    done: jax.Array,
    gamma: float = GAMMA,
) -> jax.Array:
    """Paper §7.1: L = (y_i - Q(s_i))^2, y_i = r_i + gamma * max_a Q_targ(s')."""
    q = qnet_fwd(params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    q_next = qnet_fwd(targ_params, s2)
    y = r + gamma * (1.0 - done) * jnp.max(q_next, axis=1)
    y = jax.lax.stop_gradient(y)
    return jnp.mean((y - q_sa) ** 2)


def train_step(
    params: List[jax.Array],
    targ_params: List[jax.Array],
    s: jax.Array,
    a: jax.Array,
    r: jax.Array,
    s2: jax.Array,
    done: jax.Array,
    gamma: float = GAMMA,
    lr: float = LR,
) -> Tuple[List[jax.Array], jax.Array]:
    """One SGD step of EvalNet against TargNet (AOT entry `qnet_train`).

    Returns (updated params, scalar loss).  TargNet parameters are inputs,
    never updated here — rust copies EvalNet -> TargNet every
    `target_sync_every` steps (paper: "copied directly every fixed time").
    """
    loss, grads = jax.value_and_grad(td_loss)(
        params, targ_params, s, a, r, s2, done, gamma
    )
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return new_params, loss


# --- flat-signature wrappers for AOT lowering (rust passes positional args) ---


def qnet_infer_flat(w1, b1, w2, b2, w3, b3, x):
    return (qnet_fwd([w1, b1, w2, b2, w3, b3], x),)


def qnet_train_flat(w1, b1, w2, b2, w3, b3,
                    tw1, tb1, tw2, tb2, tw3, tb3,
                    s, a, r, s2, done):
    new_params, loss = train_step(
        [w1, b1, w2, b2, w3, b3],
        [tw1, tb1, tw2, tb2, tw3, tb3],
        s, a, r, s2, done,
    )
    return (*new_params, loss)


def qnet_init_flat(seed):
    return tuple(init_params(seed))
