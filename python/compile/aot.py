"""AOT compile path: lower the L2 model (with L1 Pallas kernels inlined) to
HLO *text* artifacts the rust runtime loads via the `xla` crate.

Interchange format is HLO text, NOT `lowered.compile()`/`.serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Run once via `make artifacts`; emits
    artifacts/qnet_infer.hlo.txt         Q(s) for a single state   [1, IN]
    artifacts/qnet_infer_batch.hlo.txt   Q(s) for a camera burst   [B, IN]
    artifacts/qnet_train.hlo.txt         one DQN SGD step          batch=64
    artifacts/qnet_init.hlo.txt          seeded parameter init
    artifacts/meta.json                  dims + hyperparameters for rust
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs():
    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in model.PARAM_SHAPES]


def lower_entries():
    """Return {name: lowered} for every AOT entry point."""
    f32, i32 = jnp.float32, jnp.int32
    p = _param_specs()
    s1 = jax.ShapeDtypeStruct((1, model.IN_DIM), f32)
    sb = jax.ShapeDtypeStruct((model.INFER_BATCH, model.IN_DIM), f32)
    B = model.TRAIN_BATCH
    batch = [
        jax.ShapeDtypeStruct((B, model.IN_DIM), f32),   # s
        jax.ShapeDtypeStruct((B,), i32),                # a
        jax.ShapeDtypeStruct((B,), f32),                # r
        jax.ShapeDtypeStruct((B, model.IN_DIM), f32),   # s2
        jax.ShapeDtypeStruct((B,), f32),                # done
    ]
    return {
        "qnet_infer": jax.jit(model.qnet_infer_flat).lower(*p, s1),
        "qnet_infer_batch": jax.jit(model.qnet_infer_flat).lower(*p, sb),
        "qnet_train": jax.jit(model.qnet_train_flat).lower(*p, *p, *batch),
        "qnet_init": jax.jit(model.qnet_init_flat).lower(
            jax.ShapeDtypeStruct((), i32)
        ),
    }


def write_meta(out_dir: str) -> None:
    meta = {
        "n_slots": model.N_SLOTS,
        "task_feats": model.TASK_FEATS,
        "slot_feats": model.SLOT_FEATS,
        "in_dim": model.IN_DIM,
        "h1": model.H1,
        "h2": model.H2,
        "out_dim": model.OUT_DIM,
        "train_batch": model.TRAIN_BATCH,
        "infer_batch": model.INFER_BATCH,
        "gamma": model.GAMMA,
        "lr": model.LR,
        "param_names": model.PARAM_NAMES,
        "param_shapes": [list(s) for s in model.PARAM_SHAPES],
        "entries": [
            "qnet_infer", "qnet_infer_batch", "qnet_train", "qnet_init",
        ],
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory for *.hlo.txt + meta.json")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, lowered in lower_entries().items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"aot: wrote {path} ({len(text)} chars)")
    write_meta(args.out_dir)
    print(f"aot: wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
